//! Multi-process sharded sweeps: per-shard cell caches, advisory file
//! locks, sweep manifests, and the `fxpnet grid merge` engine.
//!
//! PR 1's `--shard I/N` scaled a sweep across the cores of one host;
//! this module scales it across *processes and machines*.  The moving
//! parts:
//!
//! * [`FileLock`] -- advisory `.lock` file (PID + hostname) protecting a
//!   cache file.  Held for the whole sweep, so concurrent processes
//!   pointed at one shared cache serialize cleanly instead of racing.
//!   A lock left behind by a dead process on the same host is detected
//!   (via procfs) and reclaimed.
//! * [`ShardedCache`] -- lock-protected [`CellCache`]: with a shard
//!   layout it writes `cache.shard-I-of-N.json` with the shard recorded
//!   in the header, so shards on different machines never share a file
//!   and `grid merge` can later verify the partition.
//! * [`SweepManifest`] -- the full sweep description (regime, arch,
//!   base seed, axes, shard layout, per-shard cell lists) as JSON.
//!   `fxpnet grid plan` prints/writes it so external schedulers (a CI
//!   matrix, a cluster) can launch one job per shard; `merge
//!   --manifest` verifies the shard files actually partition that
//!   sweep and reports exactly which cells remain.
//! * [`merge_files`] -- strict union of shard caches: hard errors on
//!   header/version mismatches and on conflicting results for the same
//!   cell (bit-compared), `*.tmp`/`*.lock` litter skipped, coverage
//!   reported.  [`MergeOutcome::to_grid`] renders the merged table
//!   without re-running anything.
//!
//! Determinism makes all of this sound: a cell's result is a pure
//! function of `(base seed, regime, w, a)`, so shards computed anywhere
//! must agree bit-for-bit wherever they overlap -- a merge conflict is
//! always a real defect (mixed versions, corruption), never noise.

use std::collections::{BTreeMap, BTreeSet};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::coordinator::grid::{grid_jobs, CellOutcome, GridResult};
use crate::coordinator::regimes::{CellEval, CellResult, Regime};
use crate::coordinator::report::{
    cell_key, parse_cache_text, CacheHeader, CellCache, CACHE_VERSION,
};
use crate::error::{FxpError, Result};
use crate::quant::policy::WidthSpec;
use crate::util::json::Json;

// -- advisory file lock -------------------------------------------------------

/// This host's name, for lock ownership records.
pub fn hostname() -> String {
    if let Ok(h) = std::fs::read_to_string("/proc/sys/kernel/hostname") {
        let h = h.trim();
        if !h.is_empty() {
            return h.to_string();
        }
    }
    std::env::var("HOSTNAME").unwrap_or_else(|_| "unknown-host".to_string())
}

/// Identity of this process's execution environment: kernel boot id +
/// pid namespace.  "pid absent from /proc" proves the owner is dead
/// only when the owner ran in *our* pid table -- a peer container can
/// share the lock's filesystem (and even our hostname) while its pids
/// are invisible to us, and reclaiming its live lock would put two
/// writers on one cache.  Empty components on platforms without procfs.
pub fn instance_id() -> String {
    static ID: std::sync::OnceLock<String> = std::sync::OnceLock::new();
    ID.get_or_init(|| {
        let boot = std::fs::read_to_string("/proc/sys/kernel/random/boot_id")
            .map(|s| s.trim().to_string())
            .unwrap_or_default();
        let pidns = std::fs::read_link("/proc/self/ns/pid")
            .map(|p| p.to_string_lossy().into_owned())
            .unwrap_or_default();
        format!("{boot}/{pidns}")
    })
    .clone()
}

/// Is `pid` alive on this host?  `None` when we cannot tell (no procfs).
fn pid_alive(pid: u64) -> Option<bool> {
    if Path::new("/proc/self").exists() {
        Some(Path::new(&format!("/proc/{pid}")).exists())
    } else {
        None
    }
}

/// How long to wait for a contended lock before erroring.
#[derive(Clone, Copy, Debug)]
pub struct LockOpts {
    pub wait: Duration,
    pub poll: Duration,
}

impl Default for LockOpts {
    fn default() -> LockOpts {
        LockOpts { wait: Duration::from_secs(10), poll: Duration::from_millis(50) }
    }
}

/// A lock file that cannot be parsed is reclaimed only after this age --
/// younger ones may simply be mid-write by their creator.
const CORRUPT_LOCK_GRACE: Duration = Duration::from_secs(10);

/// Advisory lock on a cache file: `<file>.lock` created with
/// `create_new` (atomic on POSIX and NFS-safe enough for a results
/// cache), containing the owner's PID, hostname and environment
/// ([`instance_id`]) as JSON.
///
/// Stale-lock recovery: a lock whose owner is provably dead -- same
/// host, same boot + pid namespace, PID absent from /proc -- is
/// reclaimed.  Locks from other hosts or other containers are never
/// presumed stale (we cannot check liveness there); they time out with
/// an error naming the owner.  Reclaims are serialized through a
/// short-lived `.reclaim` guard and re-verify the lock's exact content
/// before unlinking, so a waiter acting on a stale diagnosis cannot
/// unlink a lock that a new owner acquired in the meantime.
#[derive(Debug)]
pub struct FileLock {
    path: PathBuf,
}

/// The lock path guarding `target` (`cache.json` -> `cache.json.lock`).
pub fn lock_path(target: &Path) -> PathBuf {
    let mut name = target
        .file_name()
        .map(|n| n.to_os_string())
        .unwrap_or_else(|| "cache".into());
    name.push(".lock");
    target.with_file_name(name)
}

impl FileLock {
    /// Acquire the lock guarding `target`, waiting up to `opts.wait`.
    pub fn acquire(target: &Path, opts: &LockOpts) -> Result<FileLock> {
        if let Some(dir) = target.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let path = lock_path(target);
        let owner = Json::obj(vec![
            ("pid", Json::from(std::process::id() as usize)),
            ("host", Json::Str(hostname())),
            ("instance", Json::Str(instance_id())),
        ])
        .to_string();
        let deadline = Instant::now() + opts.wait;
        loop {
            match std::fs::OpenOptions::new()
                .write(true)
                .create_new(true)
                .open(&path)
            {
                Ok(mut f) => {
                    if let Err(e) = f.write_all(owner.as_bytes()) {
                        // an owner-less lock would block every waiter
                        // for the corrupt-lock grace period; undo it
                        drop(f);
                        let _ = std::fs::remove_file(&path);
                        return Err(e.into());
                    }
                    return Ok(FileLock { path });
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    if let Some((why, observed)) = Self::stale_reason(&path) {
                        if Self::try_reclaim(&path, &observed) {
                            log::warn!(
                                "reclaimed stale lock {} ({why})",
                                path.display()
                            );
                            // the lock is free now -- retry immediately,
                            // even if the deadline has passed
                            continue;
                        }
                    }
                    // the deadline also applies to the stale path: an
                    // unreclaimable stale lock must error, not spin
                    if Instant::now() >= deadline {
                        return Err(FxpError::config(format!(
                            "cache lock {} is held by {}; gave up after \
                             {:.1}s.  Another sweep is writing this cache -- \
                             point this run at its own --cache file, raise \
                             --lock-wait, or delete the lock if its owner is \
                             truly gone",
                            path.display(),
                            Self::describe_owner(&path),
                            opts.wait.as_secs_f64(),
                        )));
                    }
                    std::thread::sleep(opts.poll);
                }
                Err(e) => return Err(e.into()),
            }
        }
    }

    /// `Some((reason, exact file content))` iff the lock at `path` is
    /// provably stale.  The content is what [`FileLock::try_reclaim`]
    /// re-verifies before unlinking.
    fn stale_reason(path: &Path) -> Option<(String, String)> {
        let text = std::fs::read_to_string(path).ok()?;
        match Json::parse(&text) {
            Ok(j) => {
                let pid = j.opt("pid")?.as_usize().ok()? as u64;
                let host = j.opt("host")?.as_str().ok()?.to_string();
                let instance = j
                    .opt("instance")
                    .and_then(|x| x.as_str().ok())
                    .unwrap_or("")
                    .to_string();
                // proving death needs the owner's pid table to be ours:
                // same host AND same boot/pid-namespace
                if host == hostname()
                    && instance == instance_id()
                    && pid_alive(pid) == Some(false)
                {
                    let why = format!("owner pid {pid} in this environment is dead");
                    Some((why, text))
                } else {
                    None
                }
            }
            Err(_) => {
                // unparseable: mid-write or litter from a crashed writer
                let age = std::fs::metadata(path)
                    .and_then(|m| m.modified())
                    .ok()
                    .and_then(|t| t.elapsed().ok())?;
                if age > CORRUPT_LOCK_GRACE {
                    Some((format!("unreadable owner record, {age:.0?} old"), text))
                } else {
                    None
                }
            }
        }
    }

    /// Remove a stale lock without racing a fresh owner: serialize
    /// reclaimers through a `create_new` `.reclaim` guard and, inside
    /// it, unlink only if the lock still holds exactly the record that
    /// was diagnosed as stale.  A lock re-acquired in the meantime
    /// carries a live owner record, compares unequal, and survives.
    fn try_reclaim(lock: &Path, observed: &str) -> bool {
        let guard = {
            let mut name = lock
                .file_name()
                .map(|n| n.to_os_string())
                .unwrap_or_else(|| "lock".into());
            name.push(".reclaim");
            lock.with_file_name(name)
        };
        // a guard abandoned by a crashed reclaimer is itself removed by
        // age; the critical section below is a few syscalls
        if let Ok(meta) = std::fs::metadata(&guard) {
            let old = meta
                .modified()
                .ok()
                .and_then(|t| t.elapsed().ok())
                .is_some_and(|age| age > CORRUPT_LOCK_GRACE);
            if old {
                let _ = std::fs::remove_file(&guard);
            }
        }
        let Ok(_g) = std::fs::OpenOptions::new()
            .write(true)
            .create_new(true)
            .open(&guard)
        else {
            return false; // another process is reclaiming; let it finish
        };
        let still = std::fs::read_to_string(lock).unwrap_or_default();
        let reclaimed = still == observed && std::fs::remove_file(lock).is_ok();
        let _ = std::fs::remove_file(&guard);
        reclaimed
    }

    fn describe_owner(path: &Path) -> String {
        let parsed = std::fs::read_to_string(path)
            .ok()
            .and_then(|t| Json::parse(&t).ok());
        match parsed {
            Some(j) => format!(
                "pid {} on host {}",
                j.opt("pid")
                    .and_then(|p| p.as_usize().ok())
                    .map(|p| p.to_string())
                    .unwrap_or_else(|| "?".into()),
                j.opt("host")
                    .and_then(|h| h.as_str().ok())
                    .unwrap_or("?"),
            ),
            None => "an unknown owner".to_string(),
        }
    }
}

impl Drop for FileLock {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

// -- per-shard cache ----------------------------------------------------------

/// Validate a shard layout: positive count, `index < count`.  The
/// single definition of the `I/N` rule -- the CLI's `--shard` parsing
/// (for both `grid` and `cluster worker`), the sweep engine, and the
/// cluster handshake all reject through it, so a bad layout fails at
/// parse time with the same message everywhere.
pub fn validate_shard(index: usize, count: usize) -> Result<()> {
    if count == 0 {
        return Err(FxpError::config(format!(
            "bad shard {index}/{count}: shard count must be > 0"
        )));
    }
    if index >= count {
        return Err(FxpError::config(format!(
            "bad shard {index}/{count}: shard index {index} must be < shard \
             count {count}"
        )));
    }
    Ok(())
}

/// Per-shard cache file name: `cache.json` -> `cache.shard-I-of-N.json`.
pub fn shard_cache_path(base: &Path, index: usize, count: usize) -> PathBuf {
    let stem = base
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("cache");
    let ext = base.extension().and_then(|s| s.to_str()).unwrap_or("json");
    base.with_file_name(format!("{stem}.shard-{index}-of-{count}.{ext}"))
}

/// A lock-protected [`CellCache`].  With `split = Some((i, n))` the
/// backing file is the per-shard `cache.shard-i-of-n.json` and its
/// header records the shard layout; with `None` it is the shared
/// whole-sweep file at `base_path`.  The advisory lock is held until
/// the `ShardedCache` is dropped.
#[derive(Debug)]
pub struct ShardedCache {
    inner: CellCache,
    _lock: FileLock,
}

impl ShardedCache {
    pub fn open(
        base_path: &Path,
        arch: &str,
        regime: Regime,
        base_seed: u64,
        split: Option<(usize, usize)>,
        lock: &LockOpts,
    ) -> Result<ShardedCache> {
        let path = match split {
            Some((i, n)) => shard_cache_path(base_path, i, n),
            None => base_path.to_path_buf(),
        };
        let _lock = FileLock::acquire(&path, lock)?;
        let inner = CellCache::open_with_shard(&path, arch, regime, base_seed, split)?;
        Ok(ShardedCache { inner, _lock })
    }

    pub fn get(&self, job: &crate::coordinator::grid::CellJob) -> Option<CellResult> {
        self.inner.get(job)
    }

    pub fn put(
        &mut self,
        job: &crate::coordinator::grid::CellJob,
        res: &CellResult,
    ) {
        self.inner.put(job, res)
    }

    pub fn save(&self) -> Result<()> {
        self.inner.save()
    }

    pub fn len(&self) -> usize {
        self.inner.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.is_empty()
    }

    pub fn path(&self) -> &Path {
        self.inner.path()
    }
}

// -- sweep manifest -----------------------------------------------------------

/// Manifest schema version (independent of the cell-cache version,
/// which it also records).
pub const MANIFEST_VERSION: usize = 1;

/// Everything a scheduler needs to launch a sweep's shards, and
/// everything `merge` needs to verify they partition one sweep.
#[derive(Clone, Debug)]
pub struct SweepManifest {
    pub arch: String,
    pub regime: Regime,
    pub base_seed: u64,
    pub w_axis: Vec<String>,
    pub a_axis: Vec<String>,
    pub shard_count: usize,
    /// `shards[i]` = cell keys owned by shard `i` (round-robin over the
    /// flat grid index, matching `grid::in_shard`).
    pub shards: Vec<Vec<String>>,
}

impl SweepManifest {
    pub fn new(
        arch: &str,
        regime: Regime,
        base_seed: u64,
        shard_count: usize,
    ) -> Result<SweepManifest> {
        if shard_count == 0 {
            return Err(FxpError::config("manifest: shard count must be > 0"));
        }
        let mut shards = vec![Vec::new(); shard_count];
        for job in grid_jobs(regime, base_seed) {
            shards[job.flat % shard_count].push(CellCache::key(&job));
        }
        Ok(SweepManifest {
            arch: arch.to_string(),
            regime,
            base_seed,
            w_axis: WidthSpec::paper_axis().iter().map(|w| w.label()).collect(),
            a_axis: WidthSpec::paper_axis().iter().map(|a| a.label()).collect(),
            shard_count,
            shards,
        })
    }

    /// All cell keys of the sweep, in flat (row-major) grid order.
    pub fn expected_cells(&self) -> Vec<String> {
        let mut keys = Vec::with_capacity(self.w_axis.len() * self.a_axis.len());
        for a in &self.a_axis {
            for w in &self.w_axis {
                keys.push(cell_key(w, a));
            }
        }
        keys
    }

    /// Error unless a cache header belongs to this manifest's sweep.
    pub fn check_header(&self, path: &Path, h: &CacheHeader) -> Result<()> {
        let mut bad = Vec::new();
        if h.arch != self.arch {
            bad.push(format!("arch {} != {}", h.arch, self.arch));
        }
        if h.regime_tag != self.regime.seed_tag() {
            bad.push(format!(
                "regime tag {} != {}",
                h.regime_tag,
                self.regime.seed_tag()
            ));
        }
        if h.base_seed != self.base_seed {
            bad.push(format!("base seed {} != {}", h.base_seed, self.base_seed));
        }
        if let Some((i, n)) = h.shard {
            if n != self.shard_count {
                bad.push(format!(
                    "shard layout /{n} != manifest's /{}",
                    self.shard_count
                ));
            } else if i >= n {
                bad.push(format!("shard index {i} out of range /{n}"));
            }
        }
        if bad.is_empty() {
            Ok(())
        } else {
            Err(FxpError::config(format!(
                "{} does not belong to this manifest's sweep: {}",
                path.display(),
                bad.join("; ")
            )))
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("manifest_version", Json::from(MANIFEST_VERSION)),
            ("cache_version", Json::from(CACHE_VERSION)),
            ("arch", Json::Str(self.arch.clone())),
            ("regime", Json::Str(self.regime.label().to_string())),
            ("regime_tag", Json::from(self.regime.seed_tag() as usize)),
            ("base_seed", Json::Str(self.base_seed.to_string())),
            (
                "w_axis",
                Json::Arr(self.w_axis.iter().map(|s| Json::Str(s.clone())).collect()),
            ),
            (
                "a_axis",
                Json::Arr(self.a_axis.iter().map(|s| Json::Str(s.clone())).collect()),
            ),
            ("shard_count", Json::from(self.shard_count)),
            (
                "shards",
                Json::Arr(
                    self.shards
                        .iter()
                        .map(|cells| {
                            Json::Arr(
                                cells.iter().map(|k| Json::Str(k.clone())).collect(),
                            )
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn parse(text: &str) -> Result<SweepManifest> {
        let j = Json::parse(text)?;
        let v = j.get("manifest_version")?.as_usize()?;
        if v != MANIFEST_VERSION {
            return Err(FxpError::Json(format!(
                "manifest version {v} (supported: {MANIFEST_VERSION})"
            )));
        }
        let cv = j.get("cache_version")?.as_usize()?;
        if cv != CACHE_VERSION {
            return Err(FxpError::Json(format!(
                "manifest is for cache version {cv}, this build writes \
                 {CACHE_VERSION}; results would not be comparable"
            )));
        }
        let tag = j.get("regime_tag")?.as_usize()? as u64;
        let regime = Regime::from_seed_tag(tag)
            .ok_or_else(|| FxpError::Json(format!("unknown regime tag {tag}")))?;
        let str_vec = |key: &str| -> Result<Vec<String>> {
            j.get(key)?
                .as_arr()?
                .iter()
                .map(|s| Ok(s.as_str()?.to_string()))
                .collect()
        };
        let shard_count = j.get("shard_count")?.as_usize()?;
        let shards: Vec<Vec<String>> = j
            .get("shards")?
            .as_arr()?
            .iter()
            .map(|cells| {
                cells
                    .as_arr()?
                    .iter()
                    .map(|k| Ok(k.as_str()?.to_string()))
                    .collect()
            })
            .collect::<Result<_>>()?;
        if shard_count == 0 || shards.len() != shard_count {
            return Err(FxpError::Json(format!(
                "manifest shard lists ({}) do not match shard_count ({shard_count})",
                shards.len()
            )));
        }
        Ok(SweepManifest {
            arch: j.get("arch")?.as_str()?.to_string(),
            regime,
            base_seed: j
                .get("base_seed")?
                .as_str()?
                .parse::<u64>()
                .map_err(|_| FxpError::Json("bad base_seed".into()))?,
            w_axis: str_vec("w_axis")?,
            a_axis: str_vec("a_axis")?,
            shard_count,
            shards,
        })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<SweepManifest> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path).map_err(|e| {
            FxpError::config(format!("manifest {}: {e}", path.display()))
        })?;
        Self::parse(&text)
            .map_err(|e| FxpError::Json(format!("manifest {}: {e}", path.display())))
    }

    /// Human-readable plan: the sweep header plus one line per shard
    /// with its cell list -- what `fxpnet grid plan` prints for external
    /// schedulers.
    pub fn render(&self) -> String {
        let mut out = format!(
            "sweep plan: {} arch={} seed={} ({} cells, {} shard{})\n",
            self.regime.label(),
            self.arch,
            self.base_seed,
            self.w_axis.len() * self.a_axis.len(),
            self.shard_count,
            if self.shard_count == 1 { "" } else { "s" },
        );
        for (i, cells) in self.shards.iter().enumerate() {
            out.push_str(&format!(
                "  shard {i}/{}: {:2} cells: {}\n",
                self.shard_count,
                cells.len(),
                cells.join(" ")
            ));
        }
        out
    }
}

// -- merge --------------------------------------------------------------------

/// One cache file, strictly parsed (any schema problem is an error).
#[derive(Debug)]
pub struct ShardFile {
    pub path: PathBuf,
    pub header: CacheHeader,
    pub cells: BTreeMap<String, CellEval>,
}

/// Strictly read one cache file for merging.
pub fn read_cache_file(path: &Path) -> Result<ShardFile> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| FxpError::config(format!("{}: {e}", path.display())))?;
    let (header, cells) = parse_cache_text(&text)
        .map_err(|e| FxpError::Json(format!("{}: {e}", path.display())))?;
    Ok(ShardFile { path: path.to_path_buf(), header, cells })
}

/// What `merge_files` produced.
#[derive(Debug)]
pub struct MergeOutcome {
    pub arch: String,
    pub regime: Regime,
    pub base_seed: u64,
    pub cells: BTreeMap<String, CellEval>,
    /// cache files actually merged
    pub merged_files: usize,
    /// `*.tmp` / `*.lock` litter skipped by name
    pub skipped: Vec<PathBuf>,
    /// cells present in more than one input with bit-identical results
    pub duplicates: usize,
    /// expected cells with no result in any input (flat grid order)
    pub missing: Vec<String>,
    /// merged input files whose header declares a shard layout -- the
    /// per-shard caches a complete merge supersedes (`merge --prune`)
    pub shard_inputs: Vec<PathBuf>,
}

/// Bit-exact equality of two cached cell results ("n/a" only equals
/// "n/a", an abort only equals the same abort at the same step; floats
/// compare by `to_bits`, not by `==`): the determinism contract's
/// equality.  `grid merge` uses it to tell a harmless duplicate from a
/// corrupt shard, and the cluster coordinator uses it to check every
/// re-dispatched cell's result against what a presumed-dead worker
/// already delivered.
pub fn cells_bit_equal(a: &CellEval, b: &CellEval) -> bool {
    match (a, b) {
        (CellEval::Na, CellEval::Na) => true,
        (CellEval::Ok(x), CellEval::Ok(y)) => {
            x.n == y.n
                && x.top1_err.to_bits() == y.top1_err.to_bits()
                && x.top5_err.to_bits() == y.top5_err.to_bits()
                && x.mean_loss.to_bits() == y.mean_loss.to_bits()
        }
        (
            CellEval::Aborted { reason: ra, step: sa },
            CellEval::Aborted { reason: rb, step: sb },
        ) => ra == rb && sa == sb,
        _ => false,
    }
}

fn paper_cells() -> Vec<String> {
    let axis = WidthSpec::paper_axis();
    let mut keys = Vec::with_capacity(axis.len() * axis.len());
    for a in &axis {
        for w in &axis {
            keys.push(cell_key(&w.label(), &a.label()));
        }
    }
    keys
}

/// Union shard caches into one result set.
///
/// Strictness contract (a distributed sweep must fail loudly, never
/// publish a silently-wrong table):
/// * every input must parse and carry cache version [`CACHE_VERSION`];
/// * all inputs must describe the same sweep `(arch, regime, seed)`;
/// * the same cell appearing twice must agree bit-for-bit -- anything
///   else is a hard error naming the cell and both files;
/// * a cell outside the sweep's grid (or, with a manifest, outside its
///   file's declared shard partition) is a hard error;
/// * inputs named `*.tmp` / `*.lock` (crash litter from interrupted
///   saves) are skipped, not parsed.
pub fn merge_files(
    inputs: &[PathBuf],
    manifest: Option<&SweepManifest>,
) -> Result<MergeOutcome> {
    let mut skipped = Vec::new();
    let mut files: Vec<ShardFile> = Vec::new();
    for p in inputs {
        let name = p.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if name.ends_with(".tmp") || name.ends_with(".lock") {
            log::info!("merge: skipping temp/lock litter {}", p.display());
            skipped.push(p.clone());
            continue;
        }
        files.push(read_cache_file(p)?);
    }
    let Some(first) = files.first() else {
        return Err(FxpError::config(format!(
            "no cache files to merge ({} temp/lock inputs skipped)",
            skipped.len()
        )));
    };

    for f in &files {
        if f.header.version != CACHE_VERSION {
            return Err(FxpError::config(format!(
                "{}: cache version {} (this build merges version \
                 {CACHE_VERSION}); results across versions are not \
                 comparable -- re-run the sweep",
                f.path.display(),
                f.header.version
            )));
        }
    }
    for f in &files[1..] {
        let a = &first.header;
        let b = &f.header;
        if (a.arch.as_str(), a.regime_tag, a.base_seed)
            != (b.arch.as_str(), b.regime_tag, b.base_seed)
        {
            return Err(FxpError::config(format!(
                "{} and {} are from different sweeps: \
                 (arch={}, regime_tag={}, seed={}) vs \
                 (arch={}, regime_tag={}, seed={})",
                first.path.display(),
                f.path.display(),
                a.arch,
                a.regime_tag,
                a.base_seed,
                b.arch,
                b.regime_tag,
                b.base_seed
            )));
        }
    }
    let regime = Regime::from_seed_tag(first.header.regime_tag).ok_or_else(|| {
        FxpError::config(format!(
            "{}: unknown regime tag {}",
            first.path.display(),
            first.header.regime_tag
        ))
    })?;

    if let Some(m) = manifest {
        for f in &files {
            m.check_header(&f.path, &f.header)?;
            if let Some((i, _)) = f.header.shard {
                let allowed: BTreeSet<&str> =
                    m.shards[i].iter().map(|s| s.as_str()).collect();
                for key in f.cells.keys() {
                    if !allowed.contains(key.as_str()) {
                        return Err(FxpError::config(format!(
                            "{}: cell '{key}' is outside shard {i}'s \
                             partition -- the file does not match the \
                             manifest's shard layout",
                            f.path.display()
                        )));
                    }
                }
            }
        }
    }

    let mut cells: BTreeMap<String, CellEval> = BTreeMap::new();
    let mut owner: BTreeMap<String, PathBuf> = BTreeMap::new();
    let mut duplicates = 0usize;
    for f in &files {
        for (key, res) in &f.cells {
            match cells.get(key) {
                None => {
                    cells.insert(key.clone(), *res);
                    owner.insert(key.clone(), f.path.clone());
                }
                Some(prev) if cells_bit_equal(prev, res) => duplicates += 1,
                Some(_) => {
                    return Err(FxpError::config(format!(
                        "merge conflict at cell '{key}': {} and {} carry the \
                         same sweep header but different results -- one of \
                         them was produced by a different build or is \
                         corrupt; refusing to pick a winner",
                        owner[key].display(),
                        f.path.display()
                    )))
                }
            }
        }
    }

    let expected = match manifest {
        Some(m) => m.expected_cells(),
        None => paper_cells(),
    };
    let expected_set: BTreeSet<&str> = expected.iter().map(|s| s.as_str()).collect();
    for key in cells.keys() {
        if !expected_set.contains(key.as_str()) {
            return Err(FxpError::config(format!(
                "merged inputs contain cell '{key}', which is not part of \
                 this sweep's grid"
            )));
        }
    }
    let missing: Vec<String> = expected
        .iter()
        .filter(|k| !cells.contains_key(*k))
        .cloned()
        .collect();

    let shard_inputs: Vec<PathBuf> = files
        .iter()
        .filter(|f| f.header.shard.is_some())
        .map(|f| f.path.clone())
        .collect();

    Ok(MergeOutcome {
        arch: first.header.arch.clone(),
        regime,
        base_seed: first.header.base_seed,
        cells,
        merged_files: files.len(),
        skipped,
        duplicates,
        missing,
        shard_inputs,
    })
}

/// Delete the per-shard cache files a finished merge supersedes
/// (`fxpnet grid merge --prune`).
///
/// Refuses unless the merge covered the complete sweep: pruning inputs
/// of a partial union would destroy the only copy of those cells.  Only
/// inputs whose header declares a shard layout are deleted -- merging
/// whole-sweep caches never removes them.  Returns the deleted paths.
pub fn prune_shard_inputs(outcome: &MergeOutcome) -> Result<Vec<PathBuf>> {
    if !outcome.is_complete() {
        return Err(FxpError::config(format!(
            "refusing to prune shard caches: sweep incomplete ({} cells \
             missing: {})",
            outcome.missing.len(),
            outcome.missing.join(" ")
        )));
    }
    let mut removed = Vec::with_capacity(outcome.shard_inputs.len());
    for p in &outcome.shard_inputs {
        std::fs::remove_file(p)?;
        log::info!("pruned superseded shard cache {}", p.display());
        removed.push(p.clone());
    }
    Ok(removed)
}

impl MergeOutcome {
    /// Every expected cell accounted for -- the table is final.
    pub fn is_complete(&self) -> bool {
        self.missing.is_empty()
    }

    /// Assemble the paper-layout grid from the merged cells, without
    /// re-running anything.  Cells with no result render as "n/a".
    pub fn to_grid(&self) -> GridResult {
        let w_axis = WidthSpec::paper_axis().to_vec();
        let a_axis = WidthSpec::paper_axis().to_vec();
        let outcomes = a_axis
            .iter()
            .map(|&a| {
                w_axis
                    .iter()
                    .map(|&w| CellOutcome {
                        w,
                        a,
                        eval: self
                            .cells
                            .get(&cell_key(&w.label(), &a.label()))
                            .copied()
                            .unwrap_or(CellEval::Na),
                    })
                    .collect()
            })
            .collect();
        GridResult {
            regime: self.regime,
            arch: self.arch.clone(),
            w_axis,
            a_axis,
            outcomes,
        }
    }

    /// Write the union as a whole-sweep cache file (usable as `--cache
    /// --resume` input, or as the final record of the sweep).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        CellCache::from_parts(
            path,
            &self.arch,
            self.regime,
            self.base_seed,
            self.cells.clone(),
        )
        .save()
    }

    /// One-line coverage summary for logs and CI.
    pub fn summary(&self) -> String {
        let total = self.cells.len() + self.missing.len();
        let mut s = format!(
            "merged {} file{} ({} duplicate cell{}, {} temp/lock skipped): \
             {}/{} cells present",
            self.merged_files,
            if self.merged_files == 1 { "" } else { "s" },
            self.duplicates,
            if self.duplicates == 1 { "" } else { "s" },
            self.skipped.len(),
            self.cells.len(),
            total,
        );
        if !self.missing.is_empty() {
            s.push_str(&format!(", missing: {}", self.missing.join(" ")));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("fxp_shard_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn shard_cache_path_naming() {
        let p = shard_cache_path(Path::new("out/cache.json"), 1, 3);
        assert_eq!(p, Path::new("out/cache.shard-1-of-3.json"));
        let p = shard_cache_path(Path::new("cache"), 0, 2);
        assert_eq!(p, Path::new("cache.shard-0-of-2.json"));
    }

    #[test]
    fn manifest_round_trips_and_partitions() {
        let m = SweepManifest::new("tiny", Regime::Prop3, 42, 3).unwrap();
        assert_eq!(m.shards.len(), 3);
        let total: usize = m.shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, 16);
        // the shard lists partition the expected cells exactly
        let mut union: Vec<String> =
            m.shards.iter().flatten().cloned().collect();
        union.sort();
        let mut expected = m.expected_cells();
        expected.sort();
        assert_eq!(union, expected);

        let back = SweepManifest::parse(&m.to_json().to_string()).unwrap();
        assert_eq!(back.arch, m.arch);
        assert_eq!(back.regime, m.regime);
        assert_eq!(back.base_seed, m.base_seed);
        assert_eq!(back.shards, m.shards);
        assert!(back.render().contains("shard 2/3"));

        assert!(SweepManifest::new("tiny", Regime::Vanilla, 1, 0).is_err());
        assert!(SweepManifest::parse("{}").is_err());
    }

    #[test]
    fn manifest_header_check() {
        let m = SweepManifest::new("tiny", Regime::Vanilla, 42, 2).unwrap();
        let ok = CacheHeader {
            version: CACHE_VERSION,
            arch: "tiny".into(),
            regime_tag: Regime::Vanilla.seed_tag(),
            base_seed: 42,
            shard: Some((1, 2)),
        };
        assert!(m.check_header(Path::new("x"), &ok).is_ok());
        let mut bad = ok.clone();
        bad.base_seed = 43;
        assert!(m.check_header(Path::new("x"), &bad).is_err());
        let mut bad = ok.clone();
        bad.shard = Some((0, 3));
        assert!(m.check_header(Path::new("x"), &bad).is_err());
    }

    #[test]
    fn lock_roundtrip_and_release_on_drop() {
        let dir = temp_dir("lockdrop");
        let target = dir.join("cache.json");
        let opts = LockOpts {
            wait: Duration::from_millis(100),
            poll: Duration::from_millis(5),
        };
        {
            let _l = FileLock::acquire(&target, &opts).unwrap();
            assert!(lock_path(&target).exists());
            // held by our live pid: a second acquire must error cleanly
            let err = FileLock::acquire(&target, &opts).unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains("held by"), "{msg}");
            assert!(msg.contains(&std::process::id().to_string()), "{msg}");
        }
        assert!(!lock_path(&target).exists(), "lock not released on drop");
        let _l = FileLock::acquire(&target, &opts).unwrap();
    }

    /// Lock-file content claiming a dead owner in the given environment.
    fn dead_owner_record(instance: &str) -> String {
        // largest pid_max on Linux is 2^22; this pid cannot be alive
        format!(
            "{{\"pid\": 4194305, \"host\": \"{}\", \"instance\": \"{instance}\"}}",
            hostname()
        )
    }

    #[test]
    fn stale_lock_from_dead_pid_is_reclaimed() {
        if pid_alive(1).is_none() {
            return; // no procfs: liveness is undecidable on this platform
        }
        let dir = temp_dir("stalelock");
        let target = dir.join("cache.json");
        std::fs::write(lock_path(&target), dead_owner_record(&instance_id()))
            .unwrap();
        let opts = LockOpts {
            wait: Duration::from_millis(200),
            poll: Duration::from_millis(5),
        };
        let _l = FileLock::acquire(&target, &opts)
            .expect("stale lock should be reclaimed");
    }

    #[test]
    fn foreign_host_or_container_lock_is_never_presumed_stale() {
        let dir = temp_dir("foreignlock");
        let target = dir.join("cache.json");
        std::fs::write(
            lock_path(&target),
            "{\"pid\": 4194305, \"host\": \"some-other-machine\", \
             \"instance\": \"x\"}",
        )
        .unwrap();
        let opts = LockOpts {
            wait: Duration::from_millis(50),
            poll: Duration::from_millis(5),
        };
        let err = FileLock::acquire(&target, &opts).unwrap_err();
        assert!(err.to_string().contains("some-other-machine"));

        // same hostname but another container/boot (a peer whose pids we
        // cannot see): its dead-looking pid proves nothing, never reclaim
        std::fs::write(
            lock_path(&target),
            dead_owner_record("someone-elses-boot/pidns"),
        )
        .unwrap();
        assert!(FileLock::acquire(&target, &opts).is_err());
        // pre-instance lock formats are likewise not reclaimable
        std::fs::write(
            lock_path(&target),
            format!("{{\"pid\": 4194305, \"host\": \"{}\"}}", hostname()),
        )
        .unwrap();
        assert!(FileLock::acquire(&target, &opts).is_err());
    }

    #[test]
    fn reclaim_reverifies_content_before_unlinking() {
        let dir = temp_dir("reclaimverify");
        let target = dir.join("cache.json");
        let lock = lock_path(&target);
        let stale = dead_owner_record(&instance_id());
        std::fs::write(&lock, &stale).unwrap();
        // the lock changed hands between diagnosis and reclaim: the old
        // observation must not unlink the new owner's lock
        let fresh = "{\"pid\": 1, \"host\": \"h\", \"instance\": \"i\"}";
        std::fs::write(&lock, fresh).unwrap();
        assert!(!FileLock::try_reclaim(&lock, &stale));
        assert_eq!(std::fs::read_to_string(&lock).unwrap(), fresh);
        // unchanged content does get reclaimed
        std::fs::write(&lock, &stale).unwrap();
        assert!(FileLock::try_reclaim(&lock, &stale));
        assert!(!lock.exists());
    }
}
