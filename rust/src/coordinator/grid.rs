//! The (weight width x activation width) experiment grid -- the engine
//! behind every results table in the paper.

use std::collections::HashMap;

use crate::bench::Table;
use crate::coordinator::config::RunCfg;
use crate::coordinator::regimes::{self, CellCtx, Regime};
use crate::coordinator::evaluator::EvalResult;
use crate::error::Result;
use crate::model::params::ParamSet;
use crate::quant::calib::LayerStats;
use crate::quant::policy::WidthSpec;
use crate::data::synth::Dataset;
use crate::runtime::Engine;

/// One grid cell outcome.
#[derive(Clone, Copy, Debug)]
pub struct CellOutcome {
    pub w: WidthSpec,
    pub a: WidthSpec,
    /// None = training failed to converge (the paper's "n/a")
    pub eval: Option<EvalResult>,
}

impl CellOutcome {
    /// Error percentage string in the paper's table style.
    pub fn cell_str(&self, topk: usize) -> String {
        match &self.eval {
            None => "n/a".to_string(),
            Some(e) => {
                let err = if topk >= 5 { e.top5_err } else { e.top1_err };
                format!("{:.1}", err * 100.0)
            }
        }
    }
}

/// A completed grid.
#[derive(Clone, Debug)]
pub struct GridResult {
    pub regime: Regime,
    pub arch: String,
    pub w_axis: Vec<WidthSpec>,
    pub a_axis: Vec<WidthSpec>,
    /// outcomes[a_idx][w_idx]
    pub outcomes: Vec<Vec<CellOutcome>>,
}

impl GridResult {
    /// Render in the paper's layout: rows = activation width, cols =
    /// weight width.
    pub fn render(&self, topk: usize) -> String {
        let metric = if topk >= 5 { "Top-5" } else { "Top-1" };
        let title = format!(
            "{} -- {} error rate (%), arch {}",
            self.regime.label(),
            metric,
            self.arch
        );
        let mut header = vec!["Act \\ Wgt".to_string()];
        header.extend(self.w_axis.iter().map(|w| w.label()));
        let mut t = Table::new(
            &title,
            &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
        );
        for (ai, a) in self.a_axis.iter().enumerate() {
            let mut row = vec![a.label()];
            for wi in 0..self.w_axis.len() {
                row.push(self.outcomes[ai][wi].cell_str(topk));
            }
            t.row(row);
        }
        t.render()
    }

    pub fn cell(&self, w: WidthSpec, a: WidthSpec) -> Option<&CellOutcome> {
        let wi = self.w_axis.iter().position(|&x| x == w)?;
        let ai = self.a_axis.iter().position(|&x| x == a)?;
        Some(&self.outcomes[ai][wi])
    }
}

/// Runs grids.  Caches the float-activation fine-tuned nets ("last row
/// of Table 3") that seed Proposals 1-3, one per weight width.
pub struct GridRunner<'a> {
    pub engine: &'a Engine,
    pub arch: String,
    pub base: ParamSet,
    pub a_stats: Vec<LayerStats>,
    pub train_data: Dataset,
    pub eval_data: Dataset,
    pub cfg: RunCfg,
    p1_cache: HashMap<String, Option<ParamSet>>,
}

impl<'a> GridRunner<'a> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        engine: &'a Engine,
        arch: &str,
        base: ParamSet,
        a_stats: Vec<LayerStats>,
        train_data: Dataset,
        eval_data: Dataset,
        cfg: RunCfg,
    ) -> GridRunner<'a> {
        GridRunner {
            engine,
            arch: arch.to_string(),
            base,
            a_stats,
            train_data,
            eval_data,
            cfg,
            p1_cache: HashMap::new(),
        }
    }

    fn ctx(&self) -> CellCtx<'_> {
        CellCtx {
            engine: self.engine,
            arch: &self.arch,
            train_data: &self.train_data,
            eval_data: &self.eval_data,
            a_stats: &self.a_stats,
            cfg: &self.cfg,
        }
    }

    /// The float-activation fine-tuned net for a weight width (cached).
    pub fn p1_net(&mut self, w: WidthSpec) -> Result<Option<ParamSet>> {
        let key = w.label();
        if !self.p1_cache.contains_key(&key) {
            log::info!("training float-activation net for weights={key}");
            let ctx = CellCtx {
                engine: self.engine,
                arch: &self.arch,
                train_data: &self.train_data,
                eval_data: &self.eval_data,
                a_stats: &self.a_stats,
                cfg: &self.cfg,
            };
            let net = regimes::train_float_act_net(&ctx, &self.base, w)?;
            self.p1_cache.insert(key.clone(), net);
        }
        Ok(self.p1_cache.get(&key).unwrap().clone())
    }

    /// Run one cell under `regime`.
    pub fn run_cell(
        &mut self,
        regime: Regime,
        w: WidthSpec,
        a: WidthSpec,
    ) -> Result<CellOutcome> {
        log::info!(
            "cell [{} w={} a={}]",
            regime.label(),
            w.label(),
            a.label()
        );
        let eval = match regime {
            Regime::NoFinetune => {
                regimes::run_no_finetune(&self.ctx(), &self.base, w, a)?
            }
            Regime::Vanilla => regimes::run_vanilla(&self.ctx(), &self.base, w, a)?,
            Regime::Prop1 | Regime::Prop2 { .. } | Regime::Prop3 => {
                match self.p1_net(w)? {
                    None => None, // seed training itself diverged
                    Some(p1) => match regime {
                        Regime::Prop1 => {
                            regimes::run_prop1(&self.ctx(), &p1, w, a)?
                        }
                        Regime::Prop2 { top_layers } => {
                            regimes::run_prop2(&self.ctx(), &p1, w, a, top_layers)?
                        }
                        Regime::Prop3 => {
                            // float activations: nothing to schedule; the
                            // p1 net already IS the answer (matches the
                            // paper: the Float row repeats across 4-6)
                            if a == WidthSpec::Float {
                                regimes::run_prop1(&self.ctx(), &p1, w, a)?
                            } else {
                                regimes::run_prop3(&self.ctx(), &p1, w, a)?
                            }
                        }
                        _ => unreachable!(),
                    },
                }
            }
        };
        if let Some(e) = &eval {
            log::info!(
                "  -> top1 {:.2}% top5 {:.2}% loss {:.3}",
                e.top1_err * 100.0,
                e.top5_err * 100.0,
                e.mean_loss
            );
        } else {
            log::info!("  -> n/a (diverged)");
        }
        Ok(CellOutcome { w, a, eval })
    }

    /// Run the full paper grid for `regime`.
    pub fn run_grid(&mut self, regime: Regime) -> Result<GridResult> {
        let w_axis = WidthSpec::paper_axis().to_vec();
        let a_axis = WidthSpec::paper_axis().to_vec();
        let mut outcomes = Vec::with_capacity(a_axis.len());
        for &a in &a_axis {
            let mut row = Vec::with_capacity(w_axis.len());
            for &w in &w_axis {
                row.push(self.run_cell(regime, w, a)?);
            }
            outcomes.push(row);
        }
        Ok(GridResult {
            regime,
            arch: self.arch.clone(),
            w_axis,
            a_axis,
            outcomes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::policy::WidthSpec as W;

    fn fake_eval(err: f64) -> EvalResult {
        EvalResult { n: 100, top1_err: err, top5_err: err / 2.0, mean_loss: 1.0 }
    }

    #[test]
    fn grid_result_render_and_lookup() {
        let w_axis = W::paper_axis().to_vec();
        let a_axis = W::paper_axis().to_vec();
        let outcomes: Vec<Vec<CellOutcome>> = a_axis
            .iter()
            .enumerate()
            .map(|(ai, &a)| {
                w_axis
                    .iter()
                    .enumerate()
                    .map(|(wi, &w)| CellOutcome {
                        w,
                        a,
                        eval: if ai == 0 && wi == 0 {
                            None
                        } else {
                            Some(fake_eval(0.01 * (ai * 4 + wi) as f64))
                        },
                    })
                    .collect()
            })
            .collect();
        let g = GridResult {
            regime: Regime::Vanilla,
            arch: "tiny".into(),
            w_axis,
            a_axis,
            outcomes,
        };
        let s = g.render(1);
        assert!(s.contains("n/a"));
        assert!(s.contains("Table 3"));
        assert!(s.contains("Float"));
        // w=8 is column 1, a=4 is row 0 -> err = 0.01 * (0*4 + 1) = 1%
        let c = g.cell(W::Bits(8), W::Bits(4)).unwrap();
        assert!(c.eval.is_some());
        assert_eq!(c.cell_str(1), "1.0");
    }
}
