//! The (weight width x activation width) experiment grid -- the engine
//! behind every results table in the paper.
//!
//! Two execution paths share one cell dispatch
//! (`regimes::dispatch_cell`) and one seed tree (`cell_seed`/`p1_seed`):
//!
//! * [`GridRunner`] -- the original serial runner over a single borrowed
//!   engine (benches, one-off cells);
//! * [`run_sweep_with`] / [`ParallelGridRunner`] -- the work-queue
//!   engine: cells become [`CellJob`]s executed by a `std::thread` worker
//!   pool ([`coordinator::pool`]), with per-cell deterministic seeding,
//!   panic/divergence isolation (a dead cell is the paper's "n/a", not a
//!   dead sweep), `--shard i/n` partitioning, and a JSON cell-result
//!   cache ([`report::CellCache`]) so interrupted sweeps resume and
//!   shards union into the full table.
//!
//! Determinism contract: a cell's entire stochastic state derives from
//! `(base seed, regime, w, a)` -- never from worker identity, scheduling
//! order, shard layout, or cache hits -- so any worker count produces
//! bit-identical `CellOutcome` tables (pinned by tests/grid_parallel.rs).

use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::bench::Table;
use crate::coordinator::backend::{Backend, BackendSpec};
use crate::coordinator::config::RunCfg;
use crate::coordinator::evaluator::EvalResult;
use crate::coordinator::pool::{self, PoolStats};
use crate::coordinator::regimes::{self, CellCtx, CellEval, CellResult, Regime};
use crate::coordinator::report::CellCache;
use crate::coordinator::shard::{self, LockOpts, ShardedCache};
use crate::data::synth::Dataset;
use crate::error::{FxpError, Result};
use crate::model::checkpoint::{self, Checkpoint};
use crate::model::params::ParamSet;
use crate::quant::calib::LayerStats;
use crate::quant::policy::WidthSpec;
use crate::train::telemetry::TelemetrySummary;
use crate::util::rng;

/// Seed of one grid cell: pure function of what the cell *is*.
pub fn cell_seed(base: u64, regime: Regime, w: WidthSpec, a: WidthSpec) -> u64 {
    rng::derive_seed(
        base,
        "grid-cell",
        &[regime.seed_tag(), w.seed_tag(), a.seed_tag()],
    )
}

/// Seed of the float-activation fine-tuned net for a weight width (the
/// "last row of Table 3" that seeds Proposals 1-3).  Deliberately
/// regime-independent: Tables 4-6 share these nets.
pub fn p1_seed(base: u64, w: WidthSpec) -> u64 {
    rng::derive_seed(base, "p1-net", &[w.seed_tag()])
}

/// One unit of sweep work: a fully-described, independently-executable
/// grid cell.
#[derive(Clone, Copy, Debug)]
pub struct CellJob {
    pub regime: Regime,
    pub w: WidthSpec,
    pub a: WidthSpec,
    /// column in the result table
    pub w_idx: usize,
    /// row in the result table
    pub a_idx: usize,
    /// flat index in the unsharded grid (`a_idx * w_len + w_idx`)
    pub flat: usize,
    /// cell-scoped RNG seed (`cell_seed`)
    pub seed: u64,
}

/// All jobs of one regime's paper grid, in the serial runner's order
/// (rows = activation width, inner loop = weight width).
pub fn grid_jobs(regime: Regime, base_seed: u64) -> Vec<CellJob> {
    let w_axis = WidthSpec::paper_axis();
    let a_axis = WidthSpec::paper_axis();
    let mut jobs = Vec::with_capacity(w_axis.len() * a_axis.len());
    for (a_idx, &a) in a_axis.iter().enumerate() {
        for (w_idx, &w) in w_axis.iter().enumerate() {
            jobs.push(CellJob {
                regime,
                w,
                a,
                w_idx,
                a_idx,
                flat: a_idx * w_axis.len() + w_idx,
                seed: cell_seed(base_seed, regime, w, a),
            });
        }
    }
    jobs
}

/// One grid cell outcome.
#[derive(Clone, Copy, Debug)]
pub struct CellOutcome {
    pub w: WidthSpec,
    pub a: WidthSpec,
    /// `Na` = training failed to converge (the paper's "n/a") or, in a
    /// sharded partial sweep, a cell left to another shard; `Aborted` =
    /// the stability policy ended the cell early (rendered "div@{step}").
    pub eval: CellEval,
}

impl CellOutcome {
    /// Error percentage string in the paper's table style.
    pub fn cell_str(&self, topk: usize) -> String {
        match &self.eval {
            CellEval::Na => "n/a".to_string(),
            CellEval::Aborted { step, .. } => format!("div@{step}"),
            CellEval::Ok(e) => {
                let err = if topk >= 5 { e.top5_err } else { e.top1_err };
                format!("{:.1}", err * 100.0)
            }
        }
    }
}

/// A completed grid.
#[derive(Clone, Debug)]
pub struct GridResult {
    pub regime: Regime,
    pub arch: String,
    pub w_axis: Vec<WidthSpec>,
    pub a_axis: Vec<WidthSpec>,
    /// outcomes[a_idx][w_idx]
    pub outcomes: Vec<Vec<CellOutcome>>,
}

impl GridResult {
    /// Render in the paper's layout: rows = activation width, cols =
    /// weight width.
    pub fn render(&self, topk: usize) -> String {
        let metric = if topk >= 5 { "Top-5" } else { "Top-1" };
        let title = format!(
            "{} -- {} error rate (%), arch {}",
            self.regime.label(),
            metric,
            self.arch
        );
        let mut header = vec!["Act \\ Wgt".to_string()];
        header.extend(self.w_axis.iter().map(|w| w.label()));
        let mut t = Table::new(
            &title,
            &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
        );
        for (ai, a) in self.a_axis.iter().enumerate() {
            let mut row = vec![a.label()];
            for wi in 0..self.w_axis.len() {
                row.push(self.outcomes[ai][wi].cell_str(topk));
            }
            t.row(row);
        }
        t.render()
    }

    pub fn cell(&self, w: WidthSpec, a: WidthSpec) -> Option<&CellOutcome> {
        let wi = self.w_axis.iter().position(|&x| x == w)?;
        let ai = self.a_axis.iter().position(|&x| x == a)?;
        Some(&self.outcomes[ai][wi])
    }
}

/// Options for a parallel sweep.
#[derive(Clone, Debug, Default)]
pub struct SweepOpts {
    /// worker threads; 0 = available parallelism
    pub workers: usize,
    /// run only cells with `flat % count == index` (`--shard i/n`)
    pub shard: Option<(usize, usize)>,
    /// JSON cell-result cache: written incrementally as cells finish,
    /// consulted to merge shards into a full table.  Protected by an
    /// advisory file lock held for the whole sweep.
    pub cache_path: Option<PathBuf>,
    /// skip cells already present in the cache (`--resume`)
    pub resume: bool,
    /// with `shard`, write a per-shard `cache.shard-I-of-N.json`
    /// (derived from `cache_path`) instead of sharing one file; combine
    /// the shard files later with `fxpnet grid merge` (`--shard-cache`)
    pub split_cache: bool,
    /// how long to wait for the cache's advisory lock
    pub lock: LockOpts,
}

impl SweepOpts {
    /// Shard metadata recorded in (and required of) the cache header.
    fn cache_shard(&self) -> Option<(usize, usize)> {
        if self.split_cache {
            self.shard
        } else {
            None
        }
    }

    /// The file this sweep actually reads/writes (per-shard when
    /// `split_cache`).
    pub fn cache_file(&self) -> Option<PathBuf> {
        let base = self.cache_path.as_ref()?;
        Some(match self.cache_shard() {
            Some((i, n)) => shard::shard_cache_path(base, i, n),
            None => base.clone(),
        })
    }
}

/// Deterministic engine-free stand-in for a real training cell: a few
/// thousand seeded RNG draws whose outcome -- including the paper's
/// "diverged -> n/a" case -- is a pure function of the job's derived
/// seed.  `fxpnet grid --synthetic`, the sharded CI matrix, and the
/// parallel-sweep tests all run this one executor, so the multi-process
/// cache/merge plumbing is exercised end-to-end without artifacts or an
/// XLA runtime.
pub fn synthetic_cell(job: &CellJob) -> Result<CellResult> {
    let mut rng = rng::Rng::new(job.seed);
    let mut acc = 0.0f64;
    for _ in 0..2000 {
        acc += rng.uniform();
    }
    if rng.uniform() < 0.2 {
        return Ok(CellEval::Na); // this cell "fails to converge"
    }
    Ok(CellEval::Ok(EvalResult {
        n: 1000 + rng.below(1000),
        top1_err: rng.uniform(),
        top5_err: rng.uniform() * 0.5,
        mean_loss: acc / 1000.0,
    }))
}

/// True iff `flat` belongs to the (round-robin) shard.
pub fn in_shard(flat: usize, shard: Option<(usize, usize)>) -> bool {
    match shard {
        None => true,
        Some((index, count)) => flat % count == index,
    }
}

fn check_shard(shard: Option<(usize, usize)>) -> Result<()> {
    match shard {
        // single source of truth for the I/N rule, shared with the CLI's
        // --shard parsing and the cluster handshake
        Some((index, count)) => shard::validate_shard(index, count),
        None => Ok(()),
    }
}

/// What a sweep did, beyond the table itself.
#[derive(Debug)]
pub struct SweepOutcome {
    pub grid: GridResult,
    /// every cell with a known result (computed this run or read from
    /// the cache), keyed by [`report::cell_key`] -- the report-ready
    /// view: unlike `grid`, cells left to other shards are *absent*
    /// here instead of rendered "n/a"
    ///
    /// [`report::cell_key`]: crate::coordinator::report::cell_key
    pub cells: BTreeMap<String, CellEval>,
    /// stability-telemetry digests of cells *trained in this run* (cache
    /// hits carry none -- their telemetry lives in the stability report
    /// written when they were computed), keyed like `cells`
    pub telemetry: BTreeMap<String, TelemetrySummary>,
    /// cells executed in this run
    pub computed: usize,
    /// cells taken from the cache
    pub cached: usize,
    /// cells neither computed (other shards) nor cached -- rendered n/a
    pub missing: usize,
    /// computed cells that errored or panicked (recorded n/a)
    pub failed: usize,
    pub pool: PoolStats,
}

impl SweepOutcome {
    /// All cells of the paper grid accounted for (nothing left to other
    /// shards) -- the table is final and safe to publish.
    pub fn is_complete(&self) -> bool {
        self.missing == 0
    }
}

/// Run one regime's sweep through the worker pool with a caller-supplied
/// executor -- the testable core of the parallel engine.
///
/// * `init(worker_id)` builds one worker's private context (e.g. its own
///   PJRT engine) inside the worker thread;
/// * `run(ctx, job)` executes one cell; `Err`/panic => "n/a".
///
/// Results are keyed by cell identity, written through the optional
/// cache as they finish, and assembled into the paper's table layout.
pub fn run_sweep_with<W, I, F>(
    regime: Regime,
    arch: &str,
    base_seed: u64,
    opts: &SweepOpts,
    init: I,
    run: F,
) -> Result<SweepOutcome>
where
    I: Fn(usize) -> Result<W> + Sync,
    F: Fn(&mut W, &CellJob) -> Result<CellResult> + Sync,
{
    check_shard(opts.shard)?;
    let w_axis = WidthSpec::paper_axis().to_vec();
    let a_axis = WidthSpec::paper_axis().to_vec();
    let all = grid_jobs(regime, base_seed);

    // the advisory lock is held until the cache drops at the end of the
    // sweep, so concurrent processes sharing one cache file serialize
    let cache = match &opts.cache_path {
        Some(p) => Some(ShardedCache::open(
            p,
            arch,
            regime,
            base_seed,
            opts.cache_shard(),
            &opts.lock,
        )?),
        None => None,
    };

    // partition: cached / todo / missing (other shards, not in cache)
    let mut cached_hits: HashMap<usize, CellResult> = HashMap::new();
    let mut todo: Vec<CellJob> = Vec::new();
    let mut missing = 0usize;
    for job in &all {
        let hit = cache.as_ref().and_then(|c| c.get(job));
        if in_shard(job.flat, opts.shard) {
            match hit {
                Some(r) if opts.resume => {
                    cached_hits.insert(job.flat, r);
                }
                _ => todo.push(*job),
            }
        } else {
            match hit {
                Some(r) => {
                    cached_hits.insert(job.flat, r);
                }
                None => missing += 1,
            }
        }
    }
    log::info!(
        "sweep {}: {} cells to run, {} cached, {} left to other shards",
        regime.label(),
        todo.len(),
        cached_hits.len(),
        missing
    );

    // execute; completed cells stream into the cache so an interrupted
    // sweep resumes instead of recomputing
    let cache = Mutex::new(cache);
    let (slots, pool_stats) = pool::run_jobs(&todo, opts.workers, init, |ctx, _i, job| {
        let r = run(ctx, job);
        if let Ok(res) = &r {
            if let Some(c) = cache.lock().unwrap().as_mut() {
                c.put(job, res);
                if let Err(e) = c.save() {
                    log::warn!("cell cache save failed: {e}");
                }
            }
        }
        r
    })?;

    // panicked/errored cells become n/a -- cached too, so a resume does
    // not endlessly retry a deterministically-crashing cell
    let mut cache = cache.into_inner().unwrap();
    let mut fresh: HashMap<usize, CellResult> = HashMap::new();
    let mut failed = 0usize;
    for (job, slot) in todo.iter().zip(slots) {
        match slot {
            Some(res) => {
                fresh.insert(job.flat, res);
            }
            None => {
                failed += 1;
                // a panicked/errored recompute must not clobber a
                // previously good cached result (the failure may be
                // transient, e.g. OOM); fall back to the cache if it
                // knows better, and record "n/a" only for cells it has
                // never seen -- that still stops --resume from endlessly
                // retrying a deterministically-crashing cell
                let prev = cache.as_ref().and_then(|c| c.get(job));
                match prev {
                    Some(known) => {
                        fresh.insert(job.flat, known);
                    }
                    None => {
                        fresh.insert(job.flat, CellEval::Na);
                        if let Some(c) = cache.as_mut() {
                            c.put(job, &CellEval::Na);
                        }
                    }
                }
            }
        }
    }
    if let Some(c) = &cache {
        // a cache write failure must not discard a finished sweep's
        // results (mid-run save failures are warnings for the same
        // reason)
        if let Err(e) = c.save() {
            log::warn!("final cell cache save failed: {e}");
        }
    }

    let mut outcomes = Vec::with_capacity(a_axis.len());
    let mut cells: BTreeMap<String, CellEval> = BTreeMap::new();
    for (ai, &a) in a_axis.iter().enumerate() {
        let mut row = Vec::with_capacity(w_axis.len());
        for (wi, &w) in w_axis.iter().enumerate() {
            let flat = ai * w_axis.len() + wi;
            let known = fresh
                .get(&flat)
                .or_else(|| cached_hits.get(&flat))
                .copied();
            if let Some(eval) = known {
                cells.insert(
                    crate::coordinator::report::cell_key(&w.label(), &a.label()),
                    eval,
                );
            }
            row.push(CellOutcome { w, a, eval: known.unwrap_or(CellEval::Na) });
        }
        outcomes.push(row);
    }
    Ok(SweepOutcome {
        grid: GridResult {
            regime,
            arch: arch.to_string(),
            w_axis,
            a_axis,
            outcomes,
        },
        cells,
        telemetry: BTreeMap::new(),
        computed: todo.len(),
        cached: cached_hits.len(),
        missing,
        failed,
        pool: pool_stats,
    })
}

/// Fingerprint of everything a float-activation seed net is a function
/// of *besides* `(arch, weight width, base seed)`: the base parameters,
/// the calibration stats, the training hyperparameters, the training
/// dataset -- and the engine's stream/semantics version
/// ([`report::CACHE_VERSION`]), since the trained weights also depend on
/// the training arithmetic itself (e.g. the gradient accumulation tree
/// and the rounding-stream layout, both changed in v3).  Folded into the
/// seed-net cache file name, so a cache entry can never be silently
/// reused across a different base checkpoint, step budget, lr, dataset,
/// or engine version -- it simply becomes a different file.
pub fn p1_fingerprint(
    base: &ParamSet,
    a_stats: &[LayerStats],
    cfg: &RunCfg,
    train: &Dataset,
) -> u64 {
    fn fnv_bytes(mut h: u64, bytes: &[u8]) -> u64 {
        for &b in bytes {
            h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3);
        }
        h
    }
    fn fnv_f32s(mut h: u64, xs: &[f32]) -> u64 {
        for &x in xs {
            h = fnv_bytes(h, &x.to_bits().to_le_bytes());
        }
        h
    }
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    h = fnv_bytes(h, &(crate::coordinator::report::CACHE_VERSION as u64).to_le_bytes());
    for (name, t) in base.names.iter().zip(&base.tensors) {
        h = fnv_bytes(h, name.as_bytes());
        h = fnv_f32s(h, t.data());
    }
    for s in a_stats {
        h = fnv_f32s(h, &[s.absmax, s.meanabs, s.meansq]);
    }
    h = fnv_f32s(h, &[cfg.lr, cfg.momentum, cfg.max_loss]);
    h = fnv_bytes(h, &(cfg.finetune_steps as u64).to_le_bytes());
    h = fnv_bytes(h, &[cfg.augment as u8, cfg.method as u8]);
    h = fnv_f32s(h, train.images.data());
    for &y in train.labels.data() {
        h = fnv_bytes(h, &y.to_le_bytes());
    }
    h
}

/// Disk cache of a float-activation seed net ("p1 net"): one checkpoint
/// per (arch, weight width, base seed, [`p1_fingerprint`]), stored next
/// to the cell cache so resumed and sharded runs stop retraining the
/// most expensive part of a Proposal sweep per process.  A `.na` marker
/// records a seed training that itself diverged, so that outcome is
/// cached too.
///
/// Loading is safe because seed training is deterministic and the
/// fingerprint pins every input: a cached net is bit-identical to what
/// this process would have trained (pinned by
/// rust/tests/train_native.rs).
pub fn p1_net_path(
    dir: &Path,
    arch: &str,
    w: WidthSpec,
    base_seed: u64,
    fp: u64,
) -> PathBuf {
    dir.join(format!(
        "p1net_{arch}_w{}_seed{base_seed}_{fp:016x}.ckpt",
        w.label()
    ))
}

fn p1_na_path(dir: &Path, arch: &str, w: WidthSpec, base_seed: u64, fp: u64) -> PathBuf {
    p1_net_path(dir, arch, w, base_seed, fp).with_extension("na")
}

/// Load a cached seed net.  Outer `None` = nothing cached (train it);
/// inner `None` = cached "seed training diverged".
#[allow(clippy::too_many_arguments)]
pub fn load_p1_net(
    dir: &Path,
    arch: &str,
    expected: &[(String, Vec<usize>)],
    w: WidthSpec,
    base_seed: u64,
    fp: u64,
) -> Option<Option<ParamSet>> {
    if p1_na_path(dir, arch, w, base_seed, fp).exists() {
        return Some(None);
    }
    let path = p1_net_path(dir, arch, w, base_seed, fp);
    if !path.exists() {
        return None;
    }
    match Checkpoint::load(&path) {
        Ok(ck) => match ck.check_matches(arch, expected) {
            Ok(()) => {
                log::info!("p1 net cache hit: {}", path.display());
                Some(Some(ck.params))
            }
            Err(e) => {
                log::warn!(
                    "p1 net cache {}: wrong shape ({e}); retraining",
                    path.display()
                );
                None
            }
        },
        Err(e) => {
            // quarantine, don't propagate: a truncated/corrupt entry
            // (e.g. a crash mid-write on a pre-fsync build) must cost a
            // retrain, not a cell error -- and renaming it aside keeps
            // the evidence while letting the retrain's atomic save
            // reclaim the path
            let quarantined = path.with_file_name(format!(
                "{}.corrupt",
                path.file_name().and_then(|n| n.to_str()).unwrap_or("p1net.ckpt")
            ));
            match std::fs::rename(&path, &quarantined) {
                Ok(()) => log::warn!(
                    "p1 net cache {}: unreadable ({e}); quarantined to {}; \
                     retraining",
                    path.display(),
                    quarantined.display()
                ),
                Err(re) => log::warn!(
                    "p1 net cache {}: unreadable ({e}); quarantine rename \
                     failed ({re}); retraining",
                    path.display()
                ),
            }
            None
        }
    }
}

/// Persist a freshly-trained seed net (atomic rename, so concurrent
/// shard processes racing on the same width cannot corrupt the file --
/// and since training is deterministic, both write the same bytes).
#[allow(clippy::too_many_arguments)]
pub fn save_p1_net(
    dir: &Path,
    arch: &str,
    w: WidthSpec,
    base_seed: u64,
    fp: u64,
    steps: u64,
    net: &Option<ParamSet>,
) -> Result<()> {
    if !dir.as_os_str().is_empty() {
        std::fs::create_dir_all(dir)?;
    }
    match net {
        None => {
            let na = p1_na_path(dir, arch, w, base_seed, fp);
            std::fs::write(&na, b"")?;
            crate::util::durable::sync_parent_dir(&na)?;
        }
        Some(params) => {
            let path = p1_net_path(dir, arch, w, base_seed, fp);
            let tmp = path.with_file_name(format!(
                ".{}.{}.tmp",
                path.file_name().and_then(|n| n.to_str()).unwrap_or("p1net"),
                std::process::id()
            ));
            // save_params fsyncs the temp file; syncing the directory
            // after the rename completes the crash-durable sequence
            checkpoint::save_params(&tmp, arch, steps, params)?;
            std::fs::rename(&tmp, &path)?;
            crate::util::durable::sync_parent_dir(&path)?;
        }
    }
    Ok(())
}

/// The parallel backend-driven sweep runner: one backend instance per
/// worker (PJRT engines are single-threaded by design; the native
/// backend is cheap to build), shared read-only base net / calibration /
/// datasets.
pub struct ParallelGridRunner {
    pub backend: BackendSpec,
    pub arch: String,
    pub base: ParamSet,
    pub a_stats: Vec<LayerStats>,
    pub train_data: Dataset,
    pub eval_data: Dataset,
    pub cfg: RunCfg,
}

impl ParallelGridRunner {
    fn cell_ctx<'a>(&'a self, backend: &'a dyn Backend, seed: u64) -> CellCtx<'a> {
        CellCtx {
            backend,
            arch: &self.arch,
            train_data: &self.train_data,
            eval_data: &self.eval_data,
            a_stats: &self.a_stats,
            cfg: &self.cfg,
            cell_seed: seed,
        }
    }

    /// The sweep's seed-net cache fingerprint ([`p1_fingerprint`] of the
    /// base/calibration/config/dataset, plus the backend identity --
    /// the native and XLA engines do not produce comparable nets).
    pub fn p1_cache_fingerprint(&self) -> u64 {
        rng::derive_seed(
            p1_fingerprint(&self.base, &self.a_stats, &self.cfg, &self.train_data),
            self.backend.label(),
            &[],
        )
    }

    /// Weight widths whose p1 seed net this run will actually use: only
    /// widths with at least one in-shard cell not already satisfied by
    /// the cache.  Seed training dominates a Proposal sweep's cost, so a
    /// resumed/sharded run must not retrain nets for cells it will skip.
    fn widths_needing_p1(
        &self,
        regime: Regime,
        opts: &SweepOpts,
    ) -> Result<Vec<WidthSpec>> {
        check_shard(opts.shard)?;
        // read-only peek (no lock): saves are atomic renames, so a
        // concurrent writer can only make us retrain a net we could
        // have skipped, never corrupt what we read
        let cache = match opts.cache_file() {
            Some(p) => Some(CellCache::open_with_shard(
                p,
                &self.arch,
                regime,
                self.cfg.seed,
                opts.cache_shard(),
            )?),
            None => None,
        };
        let mut ws: Vec<WidthSpec> = Vec::new();
        for job in grid_jobs(regime, self.cfg.seed) {
            if !in_shard(job.flat, opts.shard) {
                continue;
            }
            if opts.resume && cache.as_ref().and_then(|c| c.get(&job)).is_some() {
                continue;
            }
            if !ws.contains(&job.w) {
                ws.push(job.w);
            }
        }
        Ok(ws)
    }

    /// Wave 1 of a Proposal sweep: the float-activation fine-tuned nets,
    /// one per needed weight width, trained in parallel.  A panicked/
    /// failed training slot behaves like divergence (all its cells go
    /// n/a).  With `p1_dir` set (a cell cache is in play), each worker
    /// first consults the on-disk seed-net cache and persists what it
    /// trains, so resumed/sharded processes share the work.
    fn train_p1_nets(
        &self,
        workers: usize,
        ws: Vec<WidthSpec>,
        p1_dir: Option<PathBuf>,
    ) -> Result<HashMap<String, Option<ParamSet>>> {
        log::info!("training {} float-activation seed nets", ws.len());
        let steps = self.cfg.finetune_steps as u64;
        // one fingerprint per sweep: pins base params, calibration,
        // hyperparameters, and the training set, so a stale disk entry
        // from a different run can never be mistaken for this sweep's
        let fp = p1_dir.as_ref().map(|_| self.p1_cache_fingerprint());
        let (slots, _) = pool::run_jobs(
            &ws,
            workers,
            |_wid| self.backend.build_with_threads(self.cfg.threads),
            |backend, _i, w: &WidthSpec| {
                // Float-width "seed net" is just the base net; not worth
                // a cache file
                let cacheable = *w != WidthSpec::Float;
                if let (Some(dir), Some(fp), true) = (&p1_dir, fp, cacheable) {
                    let spec = backend.arch(&self.arch)?;
                    if let Some(cached) = load_p1_net(
                        dir,
                        &self.arch,
                        &spec.params,
                        *w,
                        self.cfg.seed,
                        fp,
                    ) {
                        return Ok(cached);
                    }
                }
                let ctx = self.cell_ctx(backend.as_ref(), p1_seed(self.cfg.seed, *w));
                let net = regimes::train_float_act_net(&ctx, &self.base, *w)?;
                if let (Some(dir), Some(fp), true) = (&p1_dir, fp, cacheable) {
                    if let Err(e) = save_p1_net(
                        dir,
                        &self.arch,
                        *w,
                        self.cfg.seed,
                        fp,
                        steps,
                        &net,
                    ) {
                        log::warn!("p1 net cache save failed: {e}");
                    }
                }
                Ok(net)
            },
        )?;
        Ok(ws
            .iter()
            .zip(slots)
            .map(|(w, slot)| (w.label(), slot.flatten()))
            .collect())
    }

    /// Execute one cell job on a borrowed backend, training (and
    /// disk-caching, when `p1_dir` is set) the width's float-activation
    /// seed net on demand.  Cluster workers pull arbitrary cells one at
    /// a time, so seed nets are trained lazily per width instead of in
    /// `run_sweep`'s up-front wave; `p1` memoizes them across the
    /// worker's lifetime.  Seeding is identical to both other runners,
    /// so results are bit-identical to a single-process sweep.
    pub fn run_cell_job(
        &self,
        backend: &dyn Backend,
        p1: &mut HashMap<String, Option<ParamSet>>,
        p1_dir: Option<&Path>,
        job: &CellJob,
    ) -> Result<CellResult> {
        Ok(self.run_cell_job_full(backend, p1, p1_dir, job)?.0)
    }

    /// [`run_cell_job`](Self::run_cell_job) plus the cell's stability
    /// telemetry digest (`None` for evaluation-only regimes).
    pub fn run_cell_job_full(
        &self,
        backend: &dyn Backend,
        p1: &mut HashMap<String, Option<ParamSet>>,
        p1_dir: Option<&Path>,
        job: &CellJob,
    ) -> Result<(CellResult, Option<TelemetrySummary>)> {
        if job.regime.needs_p1_net() && !p1.contains_key(&job.w.label()) {
            // the float-width "seed net" is just the base net; not worth
            // a cache file (same rule as train_p1_nets)
            let cacheable = job.w != WidthSpec::Float;
            let fp = p1_dir.map(|_| self.p1_cache_fingerprint());
            let loaded = match (p1_dir, fp, cacheable) {
                (Some(dir), Some(fp), true) => {
                    let spec = backend.arch(&self.arch)?;
                    load_p1_net(dir, &self.arch, &spec.params, job.w, self.cfg.seed, fp)
                }
                _ => None,
            };
            let net = match loaded {
                Some(cached) => cached,
                None => {
                    let ctx =
                        self.cell_ctx(backend, p1_seed(self.cfg.seed, job.w));
                    let net = regimes::train_float_act_net(&ctx, &self.base, job.w)?;
                    if let (Some(dir), Some(fp), true) = (p1_dir, fp, cacheable) {
                        if let Err(e) = save_p1_net(
                            dir,
                            &self.arch,
                            job.w,
                            self.cfg.seed,
                            fp,
                            self.cfg.finetune_steps as u64,
                            &net,
                        ) {
                            log::warn!("p1 net cache save failed: {e}");
                        }
                    }
                    net
                }
            };
            p1.insert(job.w.label(), net);
        }
        let p1_net = if job.regime.needs_p1_net() {
            p1.get(&job.w.label()).and_then(|o| o.as_ref())
        } else {
            None
        };
        let ctx = self.cell_ctx(backend, job.seed);
        regimes::dispatch_cell_full(&ctx, job.regime, &self.base, p1_net, job.w, job.a)
    }

    /// Run the full paper grid for `regime` under `opts`.
    pub fn run_sweep(&self, regime: Regime, opts: &SweepOpts) -> Result<SweepOutcome> {
        let p1: HashMap<String, Option<ParamSet>> = if regime.needs_p1_net() {
            // seed nets live next to the cell cache (shared by shards
            // pointing at sibling cache files in one directory)
            let p1_dir = opts
                .cache_file()
                .and_then(|p| p.parent().map(Path::to_path_buf));
            self.train_p1_nets(
                opts.workers,
                self.widths_needing_p1(regime, opts)?,
                p1_dir,
            )?
        } else {
            HashMap::new()
        };
        // telemetry digests stream out of the workers by cell key; the
        // BTreeMap makes the collected set independent of completion
        // order, so the sweep's report bytes are too
        let telemetry = Mutex::new(BTreeMap::new());
        let mut outcome = run_sweep_with(
            regime,
            &self.arch,
            self.cfg.seed,
            opts,
            |_wid| self.backend.build_with_threads(self.cfg.threads),
            |backend, job| {
                let ctx = self.cell_ctx(backend.as_ref(), job.seed);
                let p1_net = p1.get(&job.w.label()).and_then(|o| o.as_ref());
                let (eval, summary) = regimes::dispatch_cell_full(
                    &ctx, job.regime, &self.base, p1_net, job.w, job.a,
                )?;
                if let Some(s) = summary {
                    telemetry
                        .lock()
                        .unwrap()
                        .insert(CellCache::key(job), s);
                }
                Ok(eval)
            },
        )?;
        outcome.telemetry = telemetry.into_inner().unwrap();
        Ok(outcome)
    }
}

/// Serial runner over one borrowed backend.  Caches the float-activation
/// fine-tuned nets ("last row of Table 3") that seed Proposals 1-3, one
/// per weight width.  Seeded identically to the parallel engine, so the
/// two produce bit-identical tables.
pub struct GridRunner<'a> {
    pub backend: &'a dyn Backend,
    pub arch: String,
    pub base: ParamSet,
    pub a_stats: Vec<LayerStats>,
    pub train_data: Dataset,
    pub eval_data: Dataset,
    pub cfg: RunCfg,
    p1_cache: HashMap<String, Option<ParamSet>>,
}

impl<'a> GridRunner<'a> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        backend: &'a dyn Backend,
        arch: &str,
        base: ParamSet,
        a_stats: Vec<LayerStats>,
        train_data: Dataset,
        eval_data: Dataset,
        cfg: RunCfg,
    ) -> GridRunner<'a> {
        GridRunner {
            backend,
            arch: arch.to_string(),
            base,
            a_stats,
            train_data,
            eval_data,
            cfg,
            p1_cache: HashMap::new(),
        }
    }

    fn ctx(&self, seed: u64) -> CellCtx<'_> {
        CellCtx {
            backend: self.backend,
            arch: &self.arch,
            train_data: &self.train_data,
            eval_data: &self.eval_data,
            a_stats: &self.a_stats,
            cfg: &self.cfg,
            cell_seed: seed,
        }
    }

    /// The float-activation fine-tuned net for a weight width (cached).
    pub fn p1_net(&mut self, w: WidthSpec) -> Result<Option<ParamSet>> {
        let key = w.label();
        if !self.p1_cache.contains_key(&key) {
            log::info!("training float-activation net for weights={key}");
            let ctx = self.ctx(p1_seed(self.cfg.seed, w));
            let net = regimes::train_float_act_net(&ctx, &self.base, w)?;
            self.p1_cache.insert(key.clone(), net);
        }
        Ok(self.p1_cache.get(&key).unwrap().clone())
    }

    /// Run one cell under `regime`.
    pub fn run_cell(
        &mut self,
        regime: Regime,
        w: WidthSpec,
        a: WidthSpec,
    ) -> Result<CellOutcome> {
        Ok(self.run_cell_full(regime, w, a)?.0)
    }

    /// [`run_cell`](Self::run_cell) plus the cell's stability telemetry
    /// digest (`None` for evaluation-only regimes).
    pub fn run_cell_full(
        &mut self,
        regime: Regime,
        w: WidthSpec,
        a: WidthSpec,
    ) -> Result<(CellOutcome, Option<TelemetrySummary>)> {
        log::info!(
            "cell [{} w={} a={}]",
            regime.label(),
            w.label(),
            a.label()
        );
        let p1 = if regime.needs_p1_net() {
            self.p1_net(w)?
        } else {
            None
        };
        let ctx = self.ctx(cell_seed(self.cfg.seed, regime, w, a));
        let (eval, summary) =
            regimes::dispatch_cell_full(&ctx, regime, &self.base, p1.as_ref(), w, a)?;
        match &eval {
            CellEval::Ok(e) => log::info!(
                "  -> top1 {:.2}% top5 {:.2}% loss {:.3}",
                e.top1_err * 100.0,
                e.top5_err * 100.0,
                e.mean_loss
            ),
            CellEval::Aborted { reason, step } => log::info!(
                "  -> aborted at step {step} ({})",
                reason.as_str()
            ),
            CellEval::Na => log::info!("  -> n/a (diverged)"),
        }
        Ok((CellOutcome { w, a, eval }, summary))
    }

    /// Run the full paper grid for `regime`, serially.
    pub fn run_grid(&mut self, regime: Regime) -> Result<GridResult> {
        Ok(self.run_grid_full(regime)?.0)
    }

    /// [`run_grid`](Self::run_grid) plus the sweep's telemetry digests
    /// keyed by [`report::cell_key`](crate::coordinator::report::cell_key).
    pub fn run_grid_full(
        &mut self,
        regime: Regime,
    ) -> Result<(GridResult, BTreeMap<String, TelemetrySummary>)> {
        let w_axis = WidthSpec::paper_axis().to_vec();
        let a_axis = WidthSpec::paper_axis().to_vec();
        let mut outcomes = Vec::with_capacity(a_axis.len());
        let mut telemetry = BTreeMap::new();
        for &a in &a_axis {
            let mut row = Vec::with_capacity(w_axis.len());
            for &w in &w_axis {
                let (outcome, summary) = self.run_cell_full(regime, w, a)?;
                if let Some(s) = summary {
                    telemetry.insert(
                        crate::coordinator::report::cell_key(
                            &w.label(),
                            &a.label(),
                        ),
                        s,
                    );
                }
                row.push(outcome);
            }
            outcomes.push(row);
        }
        Ok((
            GridResult {
                regime,
                arch: self.arch.clone(),
                w_axis,
                a_axis,
                outcomes,
            },
            telemetry,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::policy::WidthSpec as W;

    fn fake_eval(err: f64) -> EvalResult {
        EvalResult { n: 100, top1_err: err, top5_err: err / 2.0, mean_loss: 1.0 }
    }

    #[test]
    fn grid_result_render_and_lookup() {
        let w_axis = W::paper_axis().to_vec();
        let a_axis = W::paper_axis().to_vec();
        let outcomes: Vec<Vec<CellOutcome>> = a_axis
            .iter()
            .enumerate()
            .map(|(ai, &a)| {
                w_axis
                    .iter()
                    .enumerate()
                    .map(|(wi, &w)| CellOutcome {
                        w,
                        a,
                        eval: if ai == 0 && wi == 0 {
                            CellEval::Na
                        } else if ai == 1 && wi == 0 {
                            CellEval::Aborted {
                                reason:
                                    crate::coordinator::trainer::AbortReason::NanLoss,
                                step: 37,
                            }
                        } else {
                            CellEval::Ok(fake_eval(0.01 * (ai * 4 + wi) as f64))
                        },
                    })
                    .collect()
            })
            .collect();
        let g = GridResult {
            regime: Regime::Vanilla,
            arch: "tiny".into(),
            w_axis,
            a_axis,
            outcomes,
        };
        let s = g.render(1);
        assert!(s.contains("n/a"));
        assert!(s.contains("div@37"));
        assert!(s.contains("Table 3"));
        assert!(s.contains("Float"));
        // w=8 is column 1, a=4 is row 0 -> err = 0.01 * (0*4 + 1) = 1%
        let c = g.cell(W::Bits(8), W::Bits(4)).unwrap();
        assert!(c.eval.is_ok());
        assert_eq!(c.cell_str(1), "1.0");
        // the aborted cell renders its abort step
        let c = g.cell(W::Bits(4), W::Bits(8)).unwrap();
        assert_eq!(c.cell_str(1), "div@37");
    }

    #[test]
    fn jobs_cover_grid_with_distinct_seeds() {
        let jobs = grid_jobs(Regime::Vanilla, 42);
        assert_eq!(jobs.len(), 16);
        let mut seeds: Vec<u64> = jobs.iter().map(|j| j.seed).collect();
        seeds.sort();
        seeds.dedup();
        assert_eq!(seeds.len(), 16);
        for (i, j) in jobs.iter().enumerate() {
            assert_eq!(j.flat, i);
            assert_eq!(j.seed, cell_seed(42, Regime::Vanilla, j.w, j.a));
        }
        // regime-independent p1 seeds differ from every cell seed
        for j in &jobs {
            assert_ne!(j.seed, p1_seed(42, j.w));
        }
    }

    #[test]
    fn corrupt_p1_checkpoint_is_quarantined_not_fatal() {
        let dir = std::env::temp_dir().join("fxp_p1_quarantine_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = p1_net_path(&dir, "tiny", W::Bits(8), 42, 0xfeed);
        // truncated checkpoint: the magic, then EOF mid-header
        std::fs::write(&path, b"FXPCKPT1\x04").unwrap();
        let got = load_p1_net(&dir, "tiny", &[], W::Bits(8), 42, 0xfeed);
        assert!(got.is_none(), "corrupt entry must mean 'retrain'");
        assert!(!path.exists(), "corrupt file must be moved aside");
        let quarantined = dir.join(format!(
            "{}.corrupt",
            path.file_name().unwrap().to_str().unwrap()
        ));
        assert!(quarantined.exists(), "quarantined copy must be kept");
        // the path is free again: a missing entry, not an error loop
        assert!(load_p1_net(&dir, "tiny", &[], W::Bits(8), 42, 0xfeed).is_none());
        assert!(!path.exists());
    }

    #[test]
    fn shard_partition_is_exact() {
        let jobs = grid_jobs(Regime::Prop1, 7);
        for count in 1..=5usize {
            let mut seen = vec![0usize; jobs.len()];
            for index in 0..count {
                for j in &jobs {
                    if in_shard(j.flat, Some((index, count))) {
                        seen[j.flat] += 1;
                    }
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "count={count}: {seen:?}");
        }
        assert!(check_shard(Some((2, 2))).is_err());
        assert!(check_shard(Some((0, 0))).is_err());
        assert!(check_shard(Some((1, 4))).is_ok());
        assert!(check_shard(None).is_ok());
    }
}
