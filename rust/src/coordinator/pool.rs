//! Deterministic work-queue executed by a `std::thread` worker pool.
//!
//! The grid runner's execution substrate: a slice of jobs, a per-worker
//! context factory (each worker owns, e.g., its own PJRT `Engine` --
//! engines are single-threaded by design), and a job function.  Results
//! land in a slot vector indexed by job position, so the output is a pure
//! function of the jobs themselves: worker count and scheduling order
//! cannot change it.
//!
//! Failure containment (the paper's "n/a" semantics): a job that returns
//! `Err` or panics leaves its slot `None` and the sweep continues.  After
//! a panic the worker's context is re-created from the factory before it
//! takes the next job, so a trainer that died mid-step cannot leak
//! corrupt state into later cells.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::error::{FxpError, Result};

/// What happened across one `run_jobs` call.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    /// jobs submitted
    pub jobs: usize,
    /// jobs that returned Ok
    pub ok: usize,
    /// jobs that returned Err (slot = None)
    pub failed: usize,
    /// jobs that panicked (slot = None)
    pub panicked: usize,
    /// worker threads used
    pub workers: usize,
}

/// Resolve a requested worker count: 0 means "all available cores",
/// and there is never a point in more workers than jobs.
pub fn effective_workers(requested: usize, jobs: usize) -> usize {
    let auto = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let w = if requested == 0 { auto } else { requested };
    w.clamp(1, jobs.max(1))
}

/// Run `jobs` across `workers` threads (0 = available parallelism).
///
/// * `init(worker_id)` builds one worker's private context inside that
///   worker's thread (contexts need not be `Send`).
/// * `run(ctx, job_idx, job)` executes one job; `Err`/panic => `None`
///   slot.
///
/// Returns the result slots (index-aligned with `jobs`) and stats.
/// Errors only if workers died (context factory failures) before every
/// job could be attempted.
pub fn run_jobs<J, R, W, I, F>(
    jobs: &[J],
    workers: usize,
    init: I,
    run: F,
) -> Result<(Vec<Option<R>>, PoolStats)>
where
    J: Sync,
    R: Send,
    I: Fn(usize) -> Result<W> + Sync,
    F: Fn(&mut W, usize, &J) -> Result<R> + Sync,
{
    let workers = effective_workers(workers, jobs.len());
    if jobs.is_empty() {
        return Ok((Vec::new(), PoolStats { workers: 0, ..Default::default() }));
    }

    let next = AtomicUsize::new(0);
    let attempted = AtomicUsize::new(0);
    let failed = AtomicUsize::new(0);
    let panicked = AtomicUsize::new(0);
    let slots: Mutex<Vec<Option<R>>> =
        Mutex::new((0..jobs.len()).map(|_| None).collect());
    let init_errs: Mutex<Vec<FxpError>> = Mutex::new(Vec::new());

    std::thread::scope(|scope| {
        for wid in 0..workers {
            let next = &next;
            let attempted = &attempted;
            let failed = &failed;
            let panicked = &panicked;
            let slots = &slots;
            let init_errs = &init_errs;
            let init = &init;
            let run = &run;
            scope.spawn(move || {
                let mut ctx = match init(wid) {
                    Ok(c) => c,
                    Err(e) => {
                        log::warn!("worker {wid}: context init failed: {e}");
                        init_errs.lock().unwrap().push(e);
                        return;
                    }
                };
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= jobs.len() {
                        break;
                    }
                    let outcome =
                        catch_unwind(AssertUnwindSafe(|| run(&mut ctx, i, &jobs[i])));
                    attempted.fetch_add(1, Ordering::Relaxed);
                    match outcome {
                        Ok(Ok(r)) => {
                            slots.lock().unwrap()[i] = Some(r);
                        }
                        Ok(Err(e)) => {
                            log::warn!("job {i} failed (worker {wid}): {e}");
                            failed.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(_) => {
                            log::warn!("job {i} panicked (worker {wid}); isolating");
                            panicked.fetch_add(1, Ordering::Relaxed);
                            // the panic may have left ctx inconsistent
                            match init(wid) {
                                Ok(c) => ctx = c,
                                Err(e) => {
                                    log::warn!(
                                        "worker {wid}: re-init after panic \
                                         failed: {e}"
                                    );
                                    init_errs.lock().unwrap().push(e);
                                    return;
                                }
                            }
                        }
                    }
                }
            });
        }
    });

    let attempted = attempted.load(Ordering::Relaxed);
    if attempted < jobs.len() {
        let errs = init_errs.lock().unwrap();
        let detail = errs
            .first()
            .map(|e| e.to_string())
            .unwrap_or_else(|| "unknown".to_string());
        return Err(FxpError::config(format!(
            "worker pool exhausted with {} of {} jobs unattempted \
             (first worker error: {detail})",
            jobs.len() - attempted,
            jobs.len()
        )));
    }

    let slots = slots.into_inner().unwrap();
    let stats = PoolStats {
        jobs: jobs.len(),
        ok: slots.iter().filter(|s| s.is_some()).count(),
        failed: failed.load(Ordering::Relaxed),
        panicked: panicked.load(Ordering::Relaxed),
        workers,
    };
    Ok((slots, stats))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_jobs_run_and_slots_align() {
        let jobs: Vec<u64> = (0..100).collect();
        for workers in [1, 2, 4, 7] {
            let (slots, stats) =
                run_jobs(&jobs, workers, |_| Ok(()), |_, _, &j| Ok(j * 3)).unwrap();
            assert_eq!(stats.jobs, 100);
            assert_eq!(stats.ok, 100);
            assert_eq!(stats.failed + stats.panicked, 0);
            for (i, s) in slots.iter().enumerate() {
                assert_eq!(*s, Some(i as u64 * 3));
            }
        }
    }

    #[test]
    fn errors_and_panics_are_isolated() {
        let jobs: Vec<usize> = (0..40).collect();
        let (slots, stats) = run_jobs(
            &jobs,
            4,
            |_| Ok(()),
            |_, _, &j| {
                if j % 10 == 3 {
                    panic!("job {j} exploded");
                }
                if j % 10 == 7 {
                    return Err(FxpError::config("job declined"));
                }
                Ok(j)
            },
        )
        .unwrap();
        assert_eq!(stats.panicked, 4);
        assert_eq!(stats.failed, 4);
        assert_eq!(stats.ok, 32);
        for (i, s) in slots.iter().enumerate() {
            if i % 10 == 3 || i % 10 == 7 {
                assert!(s.is_none(), "slot {i}");
            } else {
                assert_eq!(*s, Some(i));
            }
        }
    }

    #[test]
    fn worker_context_recreated_after_panic() {
        // context counts jobs since (re-)init; a panic resets it
        let jobs: Vec<usize> = (0..10).collect();
        let inits = AtomicUsize::new(0);
        let (_, stats) = run_jobs(
            &jobs,
            1,
            |_| {
                inits.fetch_add(1, Ordering::Relaxed);
                Ok(0usize)
            },
            |count, _, &j| {
                *count += 1;
                if j == 4 {
                    panic!("mid-queue panic");
                }
                Ok(*count)
            },
        )
        .unwrap();
        assert_eq!(stats.panicked, 1);
        assert_eq!(inits.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn init_failure_of_all_workers_is_an_error() {
        let jobs: Vec<usize> = (0..5).collect();
        let r: Result<(Vec<Option<usize>>, PoolStats)> = run_jobs(
            &jobs,
            3,
            |_| Err(FxpError::config("no engine here")),
            |_: &mut (), _, &j| Ok(j),
        );
        assert!(r.is_err());
        assert!(r.unwrap_err().to_string().contains("unattempted"));
    }

    #[test]
    fn empty_job_list() {
        let (slots, stats) =
            run_jobs(&Vec::<u64>::new(), 4, |_| Ok(()), |_, _, &j| Ok(j)).unwrap();
        assert!(slots.is_empty());
        assert_eq!(stats.jobs, 0);
    }

    #[test]
    fn effective_worker_resolution() {
        assert_eq!(effective_workers(3, 100), 3);
        assert_eq!(effective_workers(8, 2), 2);
        assert_eq!(effective_workers(5, 0), 1);
        assert!(effective_workers(0, 1000) >= 1);
    }
}
