//! Run configuration shared by the trainer, regimes, and grid runner.

use crate::coordinator::trainer::{AbortOverlay, AbortPolicy};
use crate::quant::calib::CalibMethod;

/// Hyperparameters and workload sizes for one experiment run.
///
/// The paper explicitly performs *no* hyperparameter search per cell
/// ("we did not perform any hyperparameter optimization of the training
/// parameters"); one `RunCfg` is used for every cell of a grid.
#[derive(Clone, Debug)]
pub struct RunCfg {
    /// learning rate for fine-tuning steps
    pub lr: f32,
    /// SGD momentum
    pub momentum: f32,
    /// steps for full fine-tuning regimes (vanilla, Proposal 2)
    pub finetune_steps: usize,
    /// steps per phase of Proposal 3
    pub phase_steps: usize,
    /// pretraining steps (float baseline)
    pub pretrain_steps: usize,
    /// pretraining learning rate
    pub pretrain_lr: f32,
    /// calibration batches for activation statistics
    pub calib_batches: usize,
    /// calibration rule
    pub method: CalibMethod,
    /// divergence threshold: loss above this (or NaN/Inf) = n/a
    pub max_loss: f32,
    /// RNG seed for init/shuffling/augmentation.  Also the root of the
    /// grid's per-cell seed tree (`grid::cell_seed`); results are a pure
    /// function of this value regardless of worker count.
    pub seed: u64,
    /// worker threads for grid sweeps (0 = available parallelism)
    pub workers: usize,
    /// GEMM row-block workers *inside* one training/eval session (the
    /// unified `--threads` flag).  Orthogonal to `workers`: a sweep runs
    /// `workers` cells concurrently, each cell's session sharding its
    /// GEMMs over `threads`.  Results are bit-identical for every value
    /// -- fixed accumulation order + pre-split rounding streams -- so
    /// this is purely a performance knob (and is deliberately *not* part
    /// of any cache fingerprint).
    pub threads: usize,
    /// data augmentation during training
    pub augment: bool,
    /// end doomed fine-tuning cells early via the default
    /// [`AbortPolicy`](crate::coordinator::trainer::AbortPolicy)
    /// (`--no-early-abort` turns this off).  Never changes the numerics
    /// of cells that complete: telemetry consumes no RNG draws, and a
    /// cell the policy aborts would have ended "n/a" (or burned its full
    /// step budget diverging) anyway.
    pub early_abort: bool,
    /// per-regime abort-threshold overrides (`--abort-policy <file>`,
    /// typically learned by `fxpnet report --suggest-thresholds`);
    /// `None` keeps the built-in [`AbortPolicy::default`] everywhere.
    /// Ignored when `early_abort` is off.
    pub abort_overlay: Option<AbortOverlay>,
    /// evaluate top-k error with this k (paper reports Top-5 on 1000
    /// classes; with 10 classes we report top-1 as primary)
    pub topk: usize,
}

impl Default for RunCfg {
    fn default() -> Self {
        RunCfg {
            lr: 0.02,
            momentum: 0.9,
            finetune_steps: 200,
            phase_steps: 40,
            pretrain_steps: 800,
            pretrain_lr: 0.05,
            calib_batches: 4,
            method: CalibMethod::SqnrGaussian,
            max_loss: 20.0,
            seed: 42,
            workers: 0,
            threads: 1,
            augment: true,
            early_abort: true,
            abort_overlay: None,
            topk: 1,
        }
    }
}

impl RunCfg {
    /// Scaled-down configuration for tests and smoke benches.
    pub fn smoke() -> Self {
        RunCfg {
            finetune_steps: 8,
            phase_steps: 4,
            pretrain_steps: 20,
            calib_batches: 2,
            ..Default::default()
        }
    }

    /// The effective early-abort policy for a regime tag
    /// (`Regime::tag`): `None` under `--no-early-abort`, the overlay's
    /// resolved policy when one is loaded, the built-in default
    /// otherwise.
    pub fn abort_policy(&self, tag: &str) -> Option<AbortPolicy> {
        if !self.early_abort {
            return None;
        }
        Some(match &self.abort_overlay {
            Some(o) => o.resolve(tag),
            None => AbortPolicy::default(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = RunCfg::default();
        assert!(c.lr > 0.0 && c.lr < 1.0);
        assert!(c.finetune_steps > 0);
        assert!(c.max_loss > 3.0);
        let s = RunCfg::smoke();
        assert!(s.finetune_steps < c.finetune_steps);
        assert!(c.early_abort && s.early_abort);
    }

    #[test]
    fn abort_policy_resolution() {
        let mut c = RunCfg::default();
        assert_eq!(
            c.abort_policy("vanilla").map(|p| p.window),
            Some(AbortPolicy::default().window)
        );
        let mut overlay = AbortOverlay::default();
        overlay
            .regimes
            .insert("vanilla".into(), AbortPolicy { window: 42, ..Default::default() });
        c.abort_overlay = Some(overlay);
        assert_eq!(c.abort_policy("vanilla").map(|p| p.window), Some(42));
        // other regimes fall through to the built-in default
        assert_eq!(
            c.abort_policy("prop3").map(|p| p.window),
            Some(AbortPolicy::default().window)
        );
        c.early_abort = false;
        assert_eq!(c.abort_policy("vanilla"), None);
    }
}
