//! Proposal 3: the bottom-to-top iterative fine-tuning schedule
//! (the paper's Table 1).
//!
//! For an L-layer network there are L-1 phases.  During phase p
//! (1-indexed like the paper):
//!
//! * activations of layers 0..p are fixed point (`act_prefix = p`),
//!   everything above stays float;
//! * exactly layer p's weights update (`update_layer = p`, 0-indexed),
//!   i.e. Phase 1 fine-tunes Layer2 in the paper's 1-indexed naming;
//! * layer 0's weights are quantized but never fine-tuned.
//!
//! The invariant the schedule is designed around (checked by
//! `gradient_path_is_float`): the gradient that reaches the updating
//! layer only ever back-propagates through float-activation layers, so
//! no gradient mismatch accumulates.

/// One phase of the Table 1 schedule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Phase {
    /// 1-indexed phase number (paper naming)
    pub number: usize,
    /// layers 0..act_prefix have fixed-point activations
    pub act_prefix: usize,
    /// the (0-indexed) layer whose weights update this phase
    pub update_layer: usize,
}

impl Phase {
    /// True iff every layer the error signal crosses on its way to
    /// `update_layer`'s weight gradient has float activations.
    /// The weight gradient of layer l needs error signals from layers
    /// l..L-1; those are computed through activations of layers >= l.
    pub fn gradient_path_is_float(&self, _num_layers: usize) -> bool {
        // layers with indices < act_prefix have quantized activations; the
        // error signal reaching update_layer's weights only crosses the
        // activations of layers >= update_layer, so the path is float iff
        // the quantized prefix sits at or below the updating layer.
        self.update_layer >= self.act_prefix
    }
}

/// Build the full schedule for `num_layers` weighted layers.
pub fn schedule(num_layers: usize) -> Vec<Phase> {
    (1..num_layers)
        .map(|p| Phase { number: p, act_prefix: p, update_layer: p })
        .collect()
}

/// Render the schedule in the paper's Table 1 layout (for
/// `fxpnet report --table1` and the docs).
pub fn render_table1(num_layers: usize) -> String {
    let phases = schedule(num_layers);
    let mut t = crate::bench::Table::new(
        &format!("Table 1: iterative fine-tuning phases ({num_layers} layers)"),
        &std::iter::once("Layer".to_string())
            .chain(phases.iter().map(|p| format!("Phase {} (A/W)", p.number)))
            .collect::<Vec<_>>()
            .iter()
            .map(|s| s.as_str())
            .collect::<Vec<_>>(),
    );
    for l in (0..num_layers).rev() {
        let mut row = vec![format!("Layer{}", l + 1)];
        for p in &phases {
            let acts = if l < p.act_prefix { "FixPt" } else { "Float" };
            let wgts = if l == p.update_layer { "update" } else { "-" };
            row.push(format!("{acts}/{wgts}"));
        }
        t.row(row);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_layer_schedule_matches_paper_table1() {
        // the paper's example: 4 layers, 3 phases
        let s = schedule(4);
        assert_eq!(s.len(), 3);
        // Phase 1: Layer1 acts fixed point; Layer2 (0-indexed 1) updates
        assert_eq!(s[0], Phase { number: 1, act_prefix: 1, update_layer: 1 });
        // Phase 2: Layer1-2 acts fixed point; Layer3 updates
        assert_eq!(s[1], Phase { number: 2, act_prefix: 2, update_layer: 2 });
        // Phase 3: Layer1-3 acts fixed point; Layer4 updates
        assert_eq!(s[2], Phase { number: 3, act_prefix: 3, update_layer: 3 });
    }

    #[test]
    fn layer0_never_updates() {
        for n in 2..12 {
            assert!(schedule(n).iter().all(|p| p.update_layer != 0));
        }
    }

    #[test]
    fn every_other_layer_updates_once() {
        for n in 2..12 {
            let mut seen: Vec<usize> = schedule(n).iter().map(|p| p.update_layer).collect();
            seen.sort();
            assert_eq!(seen, (1..n).collect::<Vec<_>>());
        }
    }

    #[test]
    fn act_prefix_monotone_and_final() {
        let s = schedule(11);
        for w in s.windows(2) {
            assert!(w[1].act_prefix == w[0].act_prefix + 1);
        }
        // last phase: all but the head activation fixed point
        assert_eq!(s.last().unwrap().act_prefix, 10);
    }

    #[test]
    fn gradient_never_crosses_quantized_activation() {
        // the core design property of Proposal 3
        for n in 2..12 {
            for p in schedule(n) {
                assert!(p.gradient_path_is_float(n), "phase {p:?}");
            }
        }
    }

    #[test]
    fn table1_renders() {
        let s = render_table1(4);
        assert!(s.contains("Phase 1"));
        assert!(s.contains("Layer4"));
        // paper Table 1 spot checks: phase 1 has Layer2 updating, Layer1 FixPt
        let lines: Vec<&str> = s.lines().collect();
        let layer2 = lines.iter().find(|l| l.contains("Layer2")).unwrap();
        assert!(layer2.contains("Float/update"));
        let layer1 = lines.iter().find(|l| l.contains("Layer1")).unwrap();
        assert!(layer1.contains("FixPt/-"));
    }
}
