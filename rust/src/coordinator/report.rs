//! Result persistence: paper-style text reports, JSON dumps that the
//! bench harness and EXPERIMENTS.md consume, and the per-cell result
//! cache behind `--resume` / `--shard`.
//!
//! ## Cell cache format
//!
//! One JSON file per (regime, arch, base seed) sweep:
//!
//! ```json
//! {"version": 2, "arch": "paper12", "regime_tag": 3, "base_seed": "42",
//!  "cells": {"w=8,a=4": {"status": "ok", "n": 2048,
//!                         "top1_err": 0.334, "top5_err": 0.071,
//!                         "loss": 1.207},
//!            "w=4,a=4": {"status": "na"}}}
//! ```
//!
//! `"na"` records the paper's "failed to converge" outcome (including
//! panicked cells), so resuming never retries a deterministically-dead
//! cell.  Floats are written with Rust's shortest-round-trip formatting
//! and `base_seed` as a string, so entries reload bit-exactly; a header
//! mismatch (different sweep) discards the stale file.  Writes go
//! through a temp file + rename, making each save atomic.  Shards
//! sharing one filesystem can union through a common cache file by
//! running against it in turn; cross-process locking is future work.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::coordinator::evaluator::EvalResult;
use crate::coordinator::grid::{CellJob, GridResult};
use crate::coordinator::regimes::{CellResult, Regime};
use crate::error::{FxpError, Result};
use crate::util::json::Json;

/// Serialise a grid to JSON (for results/ dumps).
pub fn grid_to_json(g: &GridResult) -> Json {
    let mut rows = Vec::new();
    for row in &g.outcomes {
        for c in row {
            rows.push(Json::obj(vec![
                ("w", Json::Str(c.w.label())),
                ("a", Json::Str(c.a.label())),
                (
                    "top1_err",
                    match &c.eval {
                        Some(e) => Json::Num(e.top1_err),
                        None => Json::Null,
                    },
                ),
                (
                    "top5_err",
                    match &c.eval {
                        Some(e) => Json::Num(e.top5_err),
                        None => Json::Null,
                    },
                ),
                (
                    "loss",
                    match &c.eval {
                        Some(e) => Json::Num(e.mean_loss),
                        None => Json::Null,
                    },
                ),
            ]));
        }
    }
    Json::obj(vec![
        ("table", Json::from(g.regime.table_number())),
        ("regime", Json::from(g.regime.label())),
        ("arch", Json::Str(g.arch.clone())),
        ("cells", Json::Arr(rows)),
    ])
}

/// Write a grid's text + JSON forms under `dir`.
pub fn save_grid(g: &GridResult, dir: impl AsRef<Path>, topk: usize) -> Result<()> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let stem = format!("table{}_{}", g.regime.table_number(), g.arch);
    std::fs::write(dir.join(format!("{stem}.txt")), g.render(topk))?;
    std::fs::write(
        dir.join(format!("{stem}.json")),
        grid_to_json(g).to_string(),
    )?;
    log::info!("wrote {}/{stem}.{{txt,json}}", dir.display());
    Ok(())
}

/// Cell-cache schema/stream version.  Bump whenever cached results stop
/// being comparable with freshly-computed ones -- e.g. v2: the Rng
/// stream changed (Lemire `below`, integer stochastic-requantize
/// dither), so v1 cells must not union with v2 sweeps under `--resume`.
const CACHE_VERSION: usize = 2;

/// Persistent per-cell results of one sweep (see the module docs for the
/// on-disk format).
#[derive(Debug)]
pub struct CellCache {
    path: PathBuf,
    arch: String,
    regime_tag: u64,
    base_seed: u64,
    cells: BTreeMap<String, Option<EvalResult>>,
}

impl CellCache {
    /// Cache key of a cell within its sweep file.
    pub fn key(job: &CellJob) -> String {
        format!("w={},a={}", job.w.label(), job.a.label())
    }

    /// Open (or create) the cache for one sweep.  An existing file whose
    /// header does not match `(arch, regime, base_seed)` is stale (a
    /// different sweep) and is discarded with a warning.
    pub fn open(
        path: impl AsRef<Path>,
        arch: &str,
        regime: Regime,
        base_seed: u64,
    ) -> Result<CellCache> {
        let path = path.as_ref().to_path_buf();
        let mut cache = CellCache {
            path,
            arch: arch.to_string(),
            regime_tag: regime.seed_tag(),
            base_seed,
            cells: BTreeMap::new(),
        };
        if !cache.path.exists() {
            return Ok(cache);
        }
        let text = std::fs::read_to_string(&cache.path)?;
        match cache.parse_into(&text) {
            Ok(true) => {
                log::info!(
                    "cell cache {}: {} entries loaded",
                    cache.path.display(),
                    cache.cells.len()
                );
            }
            Ok(false) => {
                log::warn!(
                    "cell cache {}: header mismatch (different sweep); \
                     starting fresh",
                    cache.path.display()
                );
                cache.cells.clear();
            }
            Err(e) => {
                log::warn!(
                    "cell cache {}: unreadable ({e}); starting fresh",
                    cache.path.display()
                );
                cache.cells.clear();
            }
        }
        Ok(cache)
    }

    /// Returns Ok(false) on a header mismatch.
    fn parse_into(&mut self, text: &str) -> Result<bool> {
        let j = Json::parse(text)?;
        if j.get("version")?.as_usize()? != CACHE_VERSION
            || j.get("arch")?.as_str()? != self.arch
            || j.get("regime_tag")?.as_usize()? as u64 != self.regime_tag
            || j.get("base_seed")?.as_str()?.parse::<u64>().ok()
                != Some(self.base_seed)
        {
            return Ok(false);
        }
        for (key, cell) in j.get("cells")?.as_obj()? {
            let entry = match cell.get("status")?.as_str()? {
                "na" => None,
                "ok" => Some(EvalResult {
                    n: cell.get("n")?.as_usize()?,
                    top1_err: cell.get("top1_err")?.as_f64()?,
                    top5_err: cell.get("top5_err")?.as_f64()?,
                    mean_loss: cell.get("loss")?.as_f64()?,
                }),
                other => {
                    return Err(FxpError::Json(format!(
                        "cell '{key}': bad status '{other}'"
                    )))
                }
            };
            self.cells.insert(key.clone(), entry);
        }
        Ok(true)
    }

    /// Cached result for a cell, if any.  The outer Option is presence;
    /// the inner `CellResult` keeps the "n/a" distinction.
    pub fn get(&self, job: &CellJob) -> Option<CellResult> {
        self.cells.get(&Self::key(job)).copied()
    }

    pub fn put(&mut self, job: &CellJob, res: &CellResult) {
        // JSON cannot carry NaN/inf; a non-finite eval is the paper's
        // divergence anyway, so record it as "n/a" rather than writing a
        // token that would corrupt the file and discard the whole cache
        // on the next open.
        let entry = match res {
            Some(e)
                if !(e.top1_err.is_finite()
                    && e.top5_err.is_finite()
                    && e.mean_loss.is_finite()) =>
            {
                log::warn!(
                    "cell {}: non-finite eval cached as n/a",
                    Self::key(job)
                );
                None
            }
            other => *other,
        };
        self.cells.insert(Self::key(job), entry);
    }

    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    fn to_json(&self) -> Json {
        let mut cells = BTreeMap::new();
        for (key, entry) in &self.cells {
            let cell = match entry {
                None => Json::obj(vec![("status", Json::Str("na".into()))]),
                Some(e) => Json::obj(vec![
                    ("status", Json::Str("ok".into())),
                    ("n", Json::from(e.n)),
                    ("top1_err", Json::Num(e.top1_err)),
                    ("top5_err", Json::Num(e.top5_err)),
                    ("loss", Json::Num(e.mean_loss)),
                ]),
            };
            cells.insert(key.clone(), cell);
        }
        Json::obj(vec![
            ("version", Json::from(CACHE_VERSION)),
            ("arch", Json::Str(self.arch.clone())),
            ("regime_tag", Json::from(self.regime_tag as usize)),
            ("base_seed", Json::Str(self.base_seed.to_string())),
            ("cells", Json::Obj(cells)),
        ])
    }

    /// Atomically persist (write temp file, rename over the target).
    pub fn save(&self) -> Result<()> {
        if let Some(dir) = self.path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let tmp = self.path.with_extension("json.tmp");
        std::fs::write(&tmp, self.to_json().to_string())?;
        std::fs::rename(&tmp, &self.path)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::evaluator::EvalResult;
    use crate::coordinator::grid::CellOutcome;
    use crate::coordinator::regimes::Regime;
    use crate::quant::policy::WidthSpec as W;

    fn grid() -> GridResult {
        GridResult {
            regime: Regime::Prop3,
            arch: "tiny".into(),
            w_axis: vec![W::Bits(4), W::Float],
            a_axis: vec![W::Bits(4), W::Float],
            outcomes: vec![
                vec![
                    CellOutcome { w: W::Bits(4), a: W::Bits(4), eval: None },
                    CellOutcome {
                        w: W::Float,
                        a: W::Bits(4),
                        eval: Some(EvalResult {
                            n: 10,
                            top1_err: 0.25,
                            top5_err: 0.05,
                            mean_loss: 1.2,
                        }),
                    },
                ],
                vec![
                    CellOutcome { w: W::Bits(4), a: W::Float, eval: None },
                    CellOutcome { w: W::Float, a: W::Float, eval: None },
                ],
            ],
        }
    }

    #[test]
    fn json_round_trip() {
        let j = grid_to_json(&grid());
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("table").unwrap().as_usize().unwrap(), 6);
        let cells = parsed.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 4);
        assert_eq!(*cells[0].get("top1_err").unwrap(), Json::Null);
        assert!(
            (cells[1].get("top1_err").unwrap().as_f64().unwrap() - 0.25).abs()
                < 1e-12
        );
    }

    #[test]
    fn save_writes_files() {
        let dir = std::env::temp_dir().join("fxp_report_test");
        let _ = std::fs::remove_dir_all(&dir);
        save_grid(&grid(), &dir, 1).unwrap();
        assert!(dir.join("table6_tiny.txt").exists());
        let j = std::fs::read_to_string(dir.join("table6_tiny.json")).unwrap();
        assert!(Json::parse(&j).is_ok());
    }

    fn job(w: W, a: W) -> crate::coordinator::grid::CellJob {
        crate::coordinator::grid::CellJob {
            regime: Regime::Vanilla,
            w,
            a,
            w_idx: 0,
            a_idx: 0,
            flat: 0,
            seed: 1,
        }
    }

    #[test]
    fn cell_cache_round_trips_bit_exact() {
        let dir = std::env::temp_dir().join("fxp_cellcache_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("cache.json");
        let mut c = CellCache::open(&path, "tiny", Regime::Vanilla, 42).unwrap();
        assert!(c.is_empty());
        // awkward floats on purpose: must survive the JSON round trip
        let e = EvalResult {
            n: 2048,
            top1_err: 0.1 + 0.2,
            top5_err: 1.0 / 3.0,
            mean_loss: 1e-17,
        };
        c.put(&job(W::Bits(8), W::Bits(4)), &Some(e));
        c.put(&job(W::Bits(4), W::Bits(4)), &None);
        c.save().unwrap();

        let c2 = CellCache::open(&path, "tiny", Regime::Vanilla, 42).unwrap();
        assert_eq!(c2.len(), 2);
        assert_eq!(c2.get(&job(W::Bits(4), W::Bits(4))), Some(None));
        let back = c2.get(&job(W::Bits(8), W::Bits(4))).unwrap().unwrap();
        assert_eq!(back.n, e.n);
        assert_eq!(back.top1_err.to_bits(), e.top1_err.to_bits());
        assert_eq!(back.top5_err.to_bits(), e.top5_err.to_bits());
        assert_eq!(back.mean_loss.to_bits(), e.mean_loss.to_bits());
        // absent cell
        assert_eq!(c2.get(&job(W::Float, W::Float)), None);
    }

    #[test]
    fn cell_cache_header_mismatch_starts_fresh() {
        let dir = std::env::temp_dir().join("fxp_cellcache_hdr_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("cache.json");
        let mut c = CellCache::open(&path, "tiny", Regime::Vanilla, 42).unwrap();
        c.put(&job(W::Bits(8), W::Bits(8)), &None);
        c.save().unwrap();
        // different seed => stale
        let c2 = CellCache::open(&path, "tiny", Regime::Vanilla, 43).unwrap();
        assert!(c2.is_empty());
        // different regime => stale
        let c3 = CellCache::open(&path, "tiny", Regime::Prop1, 42).unwrap();
        assert!(c3.is_empty());
        // matching header => loaded
        let c4 = CellCache::open(&path, "tiny", Regime::Vanilla, 42).unwrap();
        assert_eq!(c4.len(), 1);
        // corrupt file => fresh, not an error
        std::fs::write(&path, "{not json").unwrap();
        let c5 = CellCache::open(&path, "tiny", Regime::Vanilla, 42).unwrap();
        assert!(c5.is_empty());
    }
}
