//! Result persistence: paper-style text reports, JSON dumps that the
//! bench harness and EXPERIMENTS.md consume, and the per-cell result
//! cache behind `--resume` / `--shard`.
//!
//! ## Cell cache format
//!
//! One JSON file per (regime, arch, base seed) sweep:
//!
//! ```json
//! {"version": 4, "arch": "paper12", "regime_tag": 3, "base_seed": "42",
//!  "cells": {"w=8,a=4": {"status": "ok", "n": 2048,
//!                         "top1_err": 0.334, "top5_err": 0.071,
//!                         "loss": 1.207},
//!            "w=4,a=4": {"status": "na"},
//!            "w=4,a=8": {"status": "aborted", "reason": "nan-loss",
//!                         "step": 37}}}
//! ```
//!
//! Per-shard caches (`--shard I/N --shard-cache`) additionally carry
//! `"shard_index"`/`"shard_count"` in the header, file names of the form
//! `cache.shard-I-of-N.json`, and are combined by `fxpnet grid merge`
//! (see [`coordinator::shard`]).
//!
//! `"na"` records the paper's "failed to converge" outcome (including
//! panicked cells), so resuming never retries a deterministically-dead
//! cell; `"aborted"` records a cell the stability policy ended early
//! (`reason` is an [`AbortReason`] string, `step` the global step the
//! predicate fired at), so resumed sweeps keep the abort provenance
//! instead of flattening it to "na".  Floats are written with Rust's
//! shortest-round-trip formatting
//! and `base_seed` as a string, so entries reload bit-exactly; a header
//! mismatch (different sweep) discards the stale file.  Writes go
//! through a uniquely-named temp file + rename, making each save atomic
//! even when several processes point at sibling paths.  Cross-process
//! sharing of one cache file is safe: the sweep engine holds the
//! advisory file lock ([`shard::FileLock`]) for the whole run, so
//! concurrent sweeps against a common cache serialize instead of
//! clobbering each other's cells.
//!
//! Two ways to read a cache file:
//! * [`CellCache::open`] -- tolerant: a mismatched or unreadable file is
//!   *stale* (a different sweep) and silently starts fresh;
//! * [`parse_cache_text`] -- strict: every schema problem is an error.
//!   `grid merge` uses this, because silently dropping a shard's results
//!   must never happen during a union.
//!
//! ## Backends and cache identity
//!
//! The header identifies a sweep by `(arch, regime, base seed)` but NOT
//! by which executor produced the cells -- the native training backend,
//! the XLA path, and `--synthetic` all share that namespace and do not
//! produce comparable numbers.  Keep per-backend sweeps in separate
//! cache files (the strict bit-exact conflict detection in `grid merge`
//! will refuse a mixed union loudly rather than pick a winner, and
//! `--resume` against the wrong backend's cache would silently keep its
//! cells).  Seed-net files (`p1net_*.ckpt`, written by the grid runner
//! next to the cell cache) do NOT have this problem: their file name
//! carries a fingerprint of the backend, base parameters,
//! hyperparameters, calibration, and dataset (`grid::p1_fingerprint`),
//! so a mismatched entry is simply a different file.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};

use crate::coordinator::evaluator::EvalResult;
use crate::coordinator::grid::{CellJob, GridResult};
use crate::coordinator::regimes::{CellEval, CellResult, Regime};
use crate::coordinator::trainer::AbortReason;
use crate::error::{FxpError, Result};
use crate::train::telemetry::TelemetrySummary;
use crate::util::json::Json;

/// Serialise a grid to JSON (for results/ dumps).
pub fn grid_to_json(g: &GridResult) -> Json {
    let mut rows = Vec::new();
    for row in &g.outcomes {
        for c in row {
            rows.push(Json::obj(vec![
                ("w", Json::Str(c.w.label())),
                ("a", Json::Str(c.a.label())),
                // Na and Aborted both serialize as null metrics: the
                // table JSON of an early-abort sweep stays byte-identical
                // to the reference full-run sweep (abort provenance lives
                // in the cell cache and the stability report instead)
                (
                    "top1_err",
                    match &c.eval {
                        CellEval::Ok(e) => Json::Num(e.top1_err),
                        _ => Json::Null,
                    },
                ),
                (
                    "top5_err",
                    match &c.eval {
                        CellEval::Ok(e) => Json::Num(e.top5_err),
                        _ => Json::Null,
                    },
                ),
                (
                    "loss",
                    match &c.eval {
                        CellEval::Ok(e) => Json::Num(e.mean_loss),
                        _ => Json::Null,
                    },
                ),
            ]));
        }
    }
    Json::obj(vec![
        ("table", Json::from(g.regime.table_number())),
        ("regime", Json::from(g.regime.label())),
        ("arch", Json::Str(g.arch.clone())),
        ("cells", Json::Arr(rows)),
    ])
}

/// Write a grid's text + JSON forms under `dir`.
pub fn save_grid(g: &GridResult, dir: impl AsRef<Path>, topk: usize) -> Result<()> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let stem = format!("table{}_{}", g.regime.table_number(), g.arch);
    std::fs::write(dir.join(format!("{stem}.txt")), g.render(topk))?;
    std::fs::write(
        dir.join(format!("{stem}.json")),
        grid_to_json(g).to_string(),
    )?;
    log::info!("wrote {}/{stem}.{{txt,json}}", dir.display());
    Ok(())
}

/// Schema version stamped into every stability report, train-telemetry
/// dump, and `fxpnet report` analytics output.  `fxpnet report` refuses
/// inputs carrying a different version rather than silently
/// misinterpreting them.  Bump whenever the report shape changes
/// incompatibly -- v2: cells became a keyed object (cache cell keys),
/// reports carry `report_version`/`kind`/`base_seed`, and training cells
/// embed a [`TelemetrySummary`] digest.
pub const REPORT_VERSION: usize = 2;

/// Flatten a grid into cache-keyed cell evals (the shape
/// [`stability_report_json`] consumes).  Useful when only a
/// [`GridResult`] is at hand, e.g. tests re-deriving a report.
pub fn grid_cells(g: &GridResult) -> BTreeMap<String, CellEval> {
    let mut cells = BTreeMap::new();
    for row in &g.outcomes {
        for c in row {
            cells.insert(cell_key(&c.w.label(), &c.a.label()), c.eval);
        }
    }
    cells
}

/// Per-cell stability report of a sweep: where the table JSON hides the
/// Na/Aborted distinction (both render as null metrics so early-abort
/// sweeps stay byte-identical to the full-run reference), this report
/// surfaces it -- status per cell (cache cell keys, [`cell_eval_to_json`]
/// encoding), abort reason/step where the policy fired, summary counts,
/// and for every cell that actually trained this run a
/// [`TelemetrySummary`] digest under `"telemetry"`.  Cells live in a
/// BTreeMap-keyed object and floats keep shortest-round-trip formatting,
/// so the report is byte-deterministic: `grid merge` regenerates the
/// identical report from merged shard caches, and `fxpnet report`
/// byte-compares reports across `--threads` / `--shard` provenance.
pub fn stability_report_json(
    arch: &str,
    regime: Regime,
    base_seed: u64,
    cells: &BTreeMap<String, CellEval>,
    telemetry: &BTreeMap<String, TelemetrySummary>,
) -> Json {
    let (mut n_ok, mut n_na, mut n_aborted) = (0usize, 0usize, 0usize);
    let mut out = BTreeMap::new();
    for (key, eval) in cells {
        let mut cell = match cell_eval_to_json(eval) {
            Json::Obj(m) => m,
            _ => unreachable!("cell_eval_to_json returns an object"),
        };
        // count the *encoded* status: a non-finite Ok flattens to "na"
        // in cell_eval_to_json, and the summary must agree with the cells
        match cell.get("status").and_then(|s| s.as_str().ok()) {
            Some("ok") => n_ok += 1,
            Some("aborted") => n_aborted += 1,
            _ => n_na += 1,
        }
        if let Some(s) = telemetry.get(key) {
            cell.insert("telemetry".into(), s.to_json());
        }
        out.insert(key.clone(), Json::Obj(cell));
    }
    Json::obj(vec![
        ("report_version", Json::from(REPORT_VERSION)),
        ("kind", Json::Str("stability".into())),
        ("table", Json::from(regime.table_number())),
        ("regime", Json::Str(regime.tag().into())),
        ("regime_tag", Json::from(regime.seed_tag() as usize)),
        ("arch", Json::Str(arch.to_string())),
        ("base_seed", Json::Str(base_seed.to_string())),
        (
            "summary",
            Json::obj(vec![
                ("ok", Json::from(n_ok)),
                ("na", Json::from(n_na)),
                ("aborted", Json::from(n_aborted)),
            ]),
        ),
        ("cells", Json::Obj(out)),
    ])
}

/// Write [`stability_report_json`] to `path` (parent dirs created).
pub fn save_stability_report(
    arch: &str,
    regime: Regime,
    base_seed: u64,
    cells: &BTreeMap<String, CellEval>,
    telemetry: &BTreeMap<String, TelemetrySummary>,
    path: impl AsRef<Path>,
) -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(
        path,
        stability_report_json(arch, regime, base_seed, cells, telemetry)
            .to_string(),
    )?;
    log::info!("wrote stability report {}", path.display());
    Ok(())
}

/// Cell-cache schema/stream version.  Bump whenever cached results stop
/// being comparable with freshly-computed ones -- e.g. v2: the Rng
/// stream changed (Lemire `below`, integer stochastic-requantize
/// dither); v3: fully quantized cells report integer-engine accuracy,
/// conv weight gradients reduce through fixed stripes, and the
/// stochastic-rounding streams are pre-split per (step, layer); v4: the
/// "aborted" cell status exists and sweeps run abort-aware by default,
/// so a v3 "na" cell is not comparable with a v4 sweep's outcome for the
/// same cell -- v3 caches must not union with v4 sweeps under
/// `--resume`.
pub const CACHE_VERSION: usize = 4;

/// Parsed header of a cell-cache file.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CacheHeader {
    pub version: usize,
    pub arch: String,
    pub regime_tag: u64,
    pub base_seed: u64,
    /// `Some((index, count))` when the file is a per-shard cache.
    pub shard: Option<(usize, usize)>,
}

/// One cell result in the cache's JSON shape -- the single encoding
/// shared by `CellCache` files and the cluster wire protocol
/// ([`cluster::proto`](crate::cluster::proto)), so a result round-trips
/// bit-exactly through either (floats keep Rust's shortest-round-trip
/// formatting).  A non-finite "ok" eval is encoded as `"na"`: JSON
/// cannot carry NaN/inf, and a non-finite eval is the paper's
/// divergence anyway.
pub fn cell_eval_to_json(entry: &CellEval) -> Json {
    match entry {
        CellEval::Na => Json::obj(vec![("status", Json::Str("na".into()))]),
        CellEval::Ok(e)
            if !(e.top1_err.is_finite()
                && e.top5_err.is_finite()
                && e.mean_loss.is_finite()) =>
        {
            Json::obj(vec![("status", Json::Str("na".into()))])
        }
        CellEval::Ok(e) => Json::obj(vec![
            ("status", Json::Str("ok".into())),
            ("n", Json::from(e.n)),
            ("top1_err", Json::Num(e.top1_err)),
            ("top5_err", Json::Num(e.top5_err)),
            ("loss", Json::Num(e.mean_loss)),
        ]),
        CellEval::Aborted { reason, step } => Json::obj(vec![
            ("status", Json::Str("aborted".into())),
            ("reason", Json::Str(reason.as_str().into())),
            ("step", Json::from(*step)),
        ]),
    }
}

/// Strictly parse one cell's JSON ([`cell_eval_to_json`]'s inverse).
/// `key` only labels errors.
pub fn cell_eval_from_json(key: &str, cell: &Json) -> Result<CellEval> {
    Ok(match cell.get("status")?.as_str()? {
        "na" => CellEval::Na,
        "ok" => CellEval::Ok(EvalResult {
            n: cell.get("n")?.as_usize()?,
            top1_err: cell.get("top1_err")?.as_f64()?,
            top5_err: cell.get("top5_err")?.as_f64()?,
            mean_loss: cell.get("loss")?.as_f64()?,
        }),
        "aborted" => {
            let rs = cell.get("reason")?.as_str()?;
            let reason = AbortReason::parse(rs).ok_or_else(|| {
                FxpError::Json(format!("cell '{key}': bad abort reason '{rs}'"))
            })?;
            CellEval::Aborted { reason, step: cell.get("step")?.as_usize()? }
        }
        other => {
            return Err(FxpError::Json(format!(
                "cell '{key}': bad status '{other}'"
            )))
        }
    })
}

/// Strictly parse a cache file's text into header + cells.  Unlike
/// `CellCache::open`, *any* schema problem is an error -- `grid merge`
/// must refuse a shard file it cannot fully account for rather than
/// silently dropping its cells.
pub fn parse_cache_text(
    text: &str,
) -> Result<(CacheHeader, BTreeMap<String, CellEval>)> {
    let j = Json::parse(text)?;
    let shard = match (j.opt("shard_index"), j.opt("shard_count")) {
        (Some(i), Some(n)) => Some((i.as_usize()?, n.as_usize()?)),
        (None, None) => None,
        _ => {
            return Err(FxpError::Json(
                "half-specified shard header (shard_index without \
                 shard_count or vice versa)"
                    .into(),
            ))
        }
    };
    let header = CacheHeader {
        version: j.get("version")?.as_usize()?,
        arch: j.get("arch")?.as_str()?.to_string(),
        regime_tag: j.get("regime_tag")?.as_usize()? as u64,
        base_seed: {
            let s = j.get("base_seed")?.as_str()?;
            s.parse::<u64>()
                .map_err(|_| FxpError::Json(format!("bad base_seed '{s}'")))?
        },
        shard,
    };
    let mut cells = BTreeMap::new();
    for (key, cell) in j.get("cells")?.as_obj()? {
        cells.insert(key.clone(), cell_eval_from_json(key, cell)?);
    }
    Ok((header, cells))
}

/// Persistent per-cell results of one sweep (see the module docs for the
/// on-disk format).
#[derive(Debug)]
pub struct CellCache {
    path: PathBuf,
    arch: String,
    regime_tag: u64,
    base_seed: u64,
    /// shard metadata written into (and required of) the header; `None`
    /// for a whole-sweep cache
    shard: Option<(usize, usize)>,
    cells: BTreeMap<String, CellEval>,
}

/// Cache key from axis labels -- the single definition of the cell-key
/// format; `CellCache::key`, the sweep manifest, and `grid merge`'s
/// coverage/table assembly all derive keys through it.
pub fn cell_key(w_label: &str, a_label: &str) -> String {
    format!("w={w_label},a={a_label}")
}

impl CellCache {
    /// Cache key of a cell within its sweep file.
    pub fn key(job: &CellJob) -> String {
        cell_key(&job.w.label(), &job.a.label())
    }

    /// Open (or create) the cache for one sweep.  An existing file whose
    /// header does not match `(arch, regime, base_seed)` is stale (a
    /// different sweep) and is discarded with a warning.
    pub fn open(
        path: impl AsRef<Path>,
        arch: &str,
        regime: Regime,
        base_seed: u64,
    ) -> Result<CellCache> {
        Self::open_with_shard(path, arch, regime, base_seed, None)
    }

    /// Like [`CellCache::open`], but for a per-shard cache file: the
    /// header must additionally carry exactly `shard`'s
    /// `(index, count)` -- a whole-sweep cache is stale for a shard
    /// opener and vice versa (their cell sets mean different things).
    pub fn open_with_shard(
        path: impl AsRef<Path>,
        arch: &str,
        regime: Regime,
        base_seed: u64,
        shard: Option<(usize, usize)>,
    ) -> Result<CellCache> {
        let path = path.as_ref().to_path_buf();
        let mut cache = CellCache {
            path,
            arch: arch.to_string(),
            regime_tag: regime.seed_tag(),
            base_seed,
            shard,
            cells: BTreeMap::new(),
        };
        if !cache.path.exists() {
            return Ok(cache);
        }
        let text = std::fs::read_to_string(&cache.path)?;
        match cache.parse_into(&text) {
            Ok(true) => {
                log::info!(
                    "cell cache {}: {} entries loaded",
                    cache.path.display(),
                    cache.cells.len()
                );
            }
            Ok(false) => {
                log::warn!(
                    "cell cache {}: header mismatch (different sweep); \
                     starting fresh",
                    cache.path.display()
                );
                cache.cells.clear();
            }
            Err(e) => {
                log::warn!(
                    "cell cache {}: unreadable ({e}); starting fresh",
                    cache.path.display()
                );
                cache.cells.clear();
            }
        }
        Ok(cache)
    }

    /// Returns Ok(false) on a header mismatch.
    fn parse_into(&mut self, text: &str) -> Result<bool> {
        let (header, cells) = parse_cache_text(text)?;
        if header
            != (CacheHeader {
                version: CACHE_VERSION,
                arch: self.arch.clone(),
                regime_tag: self.regime_tag,
                base_seed: self.base_seed,
                shard: self.shard,
            })
        {
            return Ok(false);
        }
        self.cells = cells;
        Ok(true)
    }

    /// Rebuild a cache from already-parsed parts (the `grid merge`
    /// output path).  Never reads the filesystem.
    pub fn from_parts(
        path: impl AsRef<Path>,
        arch: &str,
        regime: Regime,
        base_seed: u64,
        cells: BTreeMap<String, CellEval>,
    ) -> CellCache {
        CellCache {
            path: path.as_ref().to_path_buf(),
            arch: arch.to_string(),
            regime_tag: regime.seed_tag(),
            base_seed,
            shard: None,
            cells,
        }
    }

    /// Backing file.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Cached result for a cell, if any.  The outer Option is presence;
    /// the inner `CellResult` keeps the "n/a" and "aborted" distinctions.
    pub fn get(&self, job: &CellJob) -> Option<CellResult> {
        self.cells.get(&Self::key(job)).copied()
    }

    pub fn put(&mut self, job: &CellJob, res: &CellResult) {
        // JSON cannot carry NaN/inf; a non-finite eval is the paper's
        // divergence anyway, so record it as "n/a" rather than writing a
        // token that would corrupt the file and discard the whole cache
        // on the next open.
        let entry = match res {
            CellEval::Ok(e)
                if !(e.top1_err.is_finite()
                    && e.top5_err.is_finite()
                    && e.mean_loss.is_finite()) =>
            {
                log::warn!(
                    "cell {}: non-finite eval cached as n/a",
                    Self::key(job)
                );
                CellEval::Na
            }
            other => *other,
        };
        self.cells.insert(Self::key(job), entry);
    }

    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    fn to_json(&self) -> Json {
        let mut cells = BTreeMap::new();
        for (key, entry) in &self.cells {
            cells.insert(key.clone(), cell_eval_to_json(entry));
        }
        let mut pairs = vec![
            ("version", Json::from(CACHE_VERSION)),
            ("arch", Json::Str(self.arch.clone())),
            ("regime_tag", Json::from(self.regime_tag as usize)),
            ("base_seed", Json::Str(self.base_seed.to_string())),
            ("cells", Json::Obj(cells)),
        ];
        if let Some((index, count)) = self.shard {
            pairs.push(("shard_index", Json::from(index)));
            pairs.push(("shard_count", Json::from(count)));
        }
        Json::obj(pairs)
    }

    /// Durably persist (write temp file, fsync it, rename over the
    /// target, fsync the directory -- see [`crate::util::durable`]): a crash or
    /// power loss mid-save leaves either the previous cache or the new
    /// one, never a truncated-but-renamed file that a later `--resume`
    /// or `grid merge` would read.
    ///
    /// The temp name is unique per (process, save): `a.json` and a
    /// sibling cache `a.json.tmp` must not collide, and two processes
    /// saving sibling caches in one directory must not clobber each
    /// other's in-flight writes.  A crash can still leave `*.tmp`
    /// litter behind; `grid merge` skips such files by name.
    pub fn save(&self) -> Result<()> {
        static TMP_SEQ: AtomicUsize = AtomicUsize::new(0);
        if let Some(dir) = self.path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let name = self
            .path
            .file_name()
            .and_then(|n| n.to_str())
            .unwrap_or("cache.json");
        let tmp = self.path.with_file_name(format!(
            ".{name}.{}-{}.tmp",
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        crate::util::durable::write_atomic(
            &self.path,
            &tmp,
            self.to_json().to_string().as_bytes(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::evaluator::EvalResult;
    use crate::coordinator::grid::CellOutcome;
    use crate::coordinator::regimes::Regime;
    use crate::quant::policy::WidthSpec as W;

    fn grid() -> GridResult {
        GridResult {
            regime: Regime::Prop3,
            arch: "tiny".into(),
            w_axis: vec![W::Bits(4), W::Float],
            a_axis: vec![W::Bits(4), W::Float],
            outcomes: vec![
                vec![
                    CellOutcome {
                        w: W::Bits(4),
                        a: W::Bits(4),
                        eval: CellEval::Na,
                    },
                    CellOutcome {
                        w: W::Float,
                        a: W::Bits(4),
                        eval: CellEval::Ok(EvalResult {
                            n: 10,
                            top1_err: 0.25,
                            top5_err: 0.05,
                            mean_loss: 1.2,
                        }),
                    },
                ],
                vec![
                    CellOutcome {
                        w: W::Bits(4),
                        a: W::Float,
                        eval: CellEval::Aborted {
                            reason: AbortReason::NanLoss,
                            step: 37,
                        },
                    },
                    CellOutcome {
                        w: W::Float,
                        a: W::Float,
                        eval: CellEval::Na,
                    },
                ],
            ],
        }
    }

    #[test]
    fn json_round_trip() {
        let j = grid_to_json(&grid());
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("table").unwrap().as_usize().unwrap(), 6);
        let cells = parsed.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 4);
        assert_eq!(*cells[0].get("top1_err").unwrap(), Json::Null);
        assert!(
            (cells[1].get("top1_err").unwrap().as_f64().unwrap() - 0.25).abs()
                < 1e-12
        );
        // Aborted renders exactly like Na in the table JSON: null metrics,
        // no extra keys -- the byte-identity contract with reference runs
        assert_eq!(*cells[2].get("top1_err").unwrap(), Json::Null);
        assert!(cells[2].opt("reason").is_none());
        assert!(cells[2].opt("step").is_none());
    }

    #[test]
    fn stability_report_surfaces_abort_provenance() {
        use crate::train::telemetry::TelemetrySummary;
        let g = grid();
        let cells = grid_cells(&g);
        assert_eq!(cells.len(), 4);
        let mut telemetry = BTreeMap::new();
        telemetry.insert(
            "w=Float,a=4".to_string(),
            TelemetrySummary {
                steps: 3,
                loss_start: 2.0,
                loss_peak: 2.0,
                loss_final: 1.5,
                sat_final: 0.0,
                sat_peak: 0.1,
                ratio_min: Some(0.5),
                ratio_final: Some(0.5),
                windows: Vec::new(),
            },
        );
        let j = stability_report_json("tiny", g.regime, 42, &cells, &telemetry);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(
            parsed.get("report_version").unwrap().as_usize().unwrap(),
            REPORT_VERSION
        );
        assert_eq!(parsed.get("kind").unwrap().as_str().unwrap(), "stability");
        assert_eq!(parsed.get("regime").unwrap().as_str().unwrap(), "prop3");
        assert_eq!(parsed.get("base_seed").unwrap().as_str().unwrap(), "42");
        let summary = parsed.get("summary").unwrap();
        assert_eq!(summary.get("ok").unwrap().as_usize().unwrap(), 1);
        assert_eq!(summary.get("na").unwrap().as_usize().unwrap(), 2);
        assert_eq!(summary.get("aborted").unwrap().as_usize().unwrap(), 1);
        let out = parsed.get("cells").unwrap();
        let aborted = out.get("w=4,a=Float").unwrap();
        assert_eq!(aborted.get("status").unwrap().as_str().unwrap(), "aborted");
        assert_eq!(
            aborted.get("reason").unwrap().as_str().unwrap(),
            AbortReason::NanLoss.as_str()
        );
        assert_eq!(aborted.get("step").unwrap().as_usize().unwrap(), 37);
        // ok cells carry their metrics; the trained cell embeds its
        // telemetry digest; na cells stay bare
        let ok = out.get("w=Float,a=4").unwrap();
        assert!(ok.opt("top1_err").is_some());
        assert!(ok.opt("telemetry").is_some());
        assert!(out.get("w=4,a=4").unwrap().opt("top1_err").is_none());
        // deterministic serialization: two renders are byte-identical
        assert_eq!(
            j.to_string(),
            stability_report_json("tiny", g.regime, 42, &cells, &telemetry)
                .to_string()
        );
    }

    #[test]
    fn stability_report_saves_to_nested_path() {
        let dir = std::env::temp_dir().join("fxp_stability_report_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("nested").join("stability_tiny.json");
        let g = grid();
        let cells = grid_cells(&g);
        let telemetry = BTreeMap::new();
        save_stability_report("tiny", g.regime, 42, &cells, &telemetry, &path)
            .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(
            text,
            stability_report_json("tiny", g.regime, 42, &cells, &telemetry)
                .to_string()
        );
    }

    #[test]
    fn save_writes_files() {
        let dir = std::env::temp_dir().join("fxp_report_test");
        let _ = std::fs::remove_dir_all(&dir);
        save_grid(&grid(), &dir, 1).unwrap();
        assert!(dir.join("table6_tiny.txt").exists());
        let j = std::fs::read_to_string(dir.join("table6_tiny.json")).unwrap();
        assert!(Json::parse(&j).is_ok());
    }

    fn job(w: W, a: W) -> crate::coordinator::grid::CellJob {
        crate::coordinator::grid::CellJob {
            regime: Regime::Vanilla,
            w,
            a,
            w_idx: 0,
            a_idx: 0,
            flat: 0,
            seed: 1,
        }
    }

    #[test]
    fn cell_cache_round_trips_bit_exact() {
        let dir = std::env::temp_dir().join("fxp_cellcache_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("cache.json");
        let mut c = CellCache::open(&path, "tiny", Regime::Vanilla, 42).unwrap();
        assert!(c.is_empty());
        // awkward floats on purpose: must survive the JSON round trip
        let e = EvalResult {
            n: 2048,
            top1_err: 0.1 + 0.2,
            top5_err: 1.0 / 3.0,
            mean_loss: 1e-17,
        };
        c.put(&job(W::Bits(8), W::Bits(4)), &CellEval::Ok(e));
        c.put(&job(W::Bits(4), W::Bits(4)), &CellEval::Na);
        c.save().unwrap();

        let c2 = CellCache::open(&path, "tiny", Regime::Vanilla, 42).unwrap();
        assert_eq!(c2.len(), 2);
        assert_eq!(c2.get(&job(W::Bits(4), W::Bits(4))), Some(CellEval::Na));
        let back = c2.get(&job(W::Bits(8), W::Bits(4))).unwrap().ok().unwrap();
        assert_eq!(back.n, e.n);
        assert_eq!(back.top1_err.to_bits(), e.top1_err.to_bits());
        assert_eq!(back.top5_err.to_bits(), e.top5_err.to_bits());
        assert_eq!(back.mean_loss.to_bits(), e.mean_loss.to_bits());
        // absent cell
        assert_eq!(c2.get(&job(W::Float, W::Float)), None);
    }

    #[test]
    fn cell_cache_round_trips_aborted_status() {
        let dir = std::env::temp_dir().join("fxp_cellcache_abort_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("cache.json");
        let mut c = CellCache::open(&path, "tiny", Regime::Vanilla, 42).unwrap();
        let aborted =
            CellEval::Aborted { reason: AbortReason::LossBlowup, step: 129 };
        c.put(&job(W::Bits(4), W::Bits(8)), &aborted);
        c.save().unwrap();

        // tolerant reader keeps the full provenance
        let c2 = CellCache::open(&path, "tiny", Regime::Vanilla, 42).unwrap();
        assert_eq!(c2.get(&job(W::Bits(4), W::Bits(8))), Some(aborted));

        // strict reader sees the same entry, and a corrupted reason is a
        // hard error (grid merge must not silently drop abort provenance)
        let text = std::fs::read_to_string(&path).unwrap();
        let (h, cells) = parse_cache_text(&text).unwrap();
        assert_eq!(h.version, CACHE_VERSION);
        assert_eq!(cells.get("w=4,a=8"), Some(&aborted));
        let bad = text.replace("loss-blowup", "mystery-reason");
        assert!(parse_cache_text(&bad).is_err());
    }

    #[test]
    fn put_flattens_non_finite_eval_to_na() {
        let dir = std::env::temp_dir().join("fxp_cellcache_nonfinite_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut c = CellCache::open(dir.join("cache.json"), "tiny", Regime::Vanilla, 42)
            .unwrap();
        let e = EvalResult {
            n: 10,
            top1_err: f64::NAN,
            top5_err: 0.1,
            mean_loss: 1.0,
        };
        c.put(&job(W::Bits(4), W::Bits(4)), &CellEval::Ok(e));
        assert_eq!(c.get(&job(W::Bits(4), W::Bits(4))), Some(CellEval::Na));
    }

    #[test]
    fn cell_cache_header_mismatch_starts_fresh() {
        let dir = std::env::temp_dir().join("fxp_cellcache_hdr_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("cache.json");
        let mut c = CellCache::open(&path, "tiny", Regime::Vanilla, 42).unwrap();
        c.put(&job(W::Bits(8), W::Bits(8)), &CellEval::Na);
        c.save().unwrap();
        // different seed => stale
        let c2 = CellCache::open(&path, "tiny", Regime::Vanilla, 43).unwrap();
        assert!(c2.is_empty());
        // different regime => stale
        let c3 = CellCache::open(&path, "tiny", Regime::Prop1, 42).unwrap();
        assert!(c3.is_empty());
        // matching header => loaded
        let c4 = CellCache::open(&path, "tiny", Regime::Vanilla, 42).unwrap();
        assert_eq!(c4.len(), 1);
        // corrupt file => fresh, not an error
        std::fs::write(&path, "{not json").unwrap();
        let c5 = CellCache::open(&path, "tiny", Regime::Vanilla, 42).unwrap();
        assert!(c5.is_empty());
    }

    #[test]
    fn shard_header_round_trips_and_separates_from_whole_sweep() {
        let dir = std::env::temp_dir().join("fxp_cellcache_shard_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("cache.shard-1-of-3.json");
        let mut c = CellCache::open_with_shard(
            &path,
            "tiny",
            Regime::Vanilla,
            42,
            Some((1, 3)),
        )
        .unwrap();
        c.put(&job(W::Bits(8), W::Bits(8)), &CellEval::Na);
        c.save().unwrap();

        // strict reader sees the shard metadata
        let text = std::fs::read_to_string(&path).unwrap();
        let (h, cells) = parse_cache_text(&text).unwrap();
        assert_eq!(h.shard, Some((1, 3)));
        assert_eq!(h.version, CACHE_VERSION);
        assert_eq!(cells.len(), 1);

        // same shard reloads; other layouts and whole-sweep openers see
        // a stale file
        let same =
            CellCache::open_with_shard(&path, "tiny", Regime::Vanilla, 42, Some((1, 3)))
                .unwrap();
        assert_eq!(same.len(), 1);
        let other =
            CellCache::open_with_shard(&path, "tiny", Regime::Vanilla, 42, Some((2, 3)))
                .unwrap();
        assert!(other.is_empty());
        let whole = CellCache::open(&path, "tiny", Regime::Vanilla, 42).unwrap();
        assert!(whole.is_empty());
    }

    #[test]
    fn save_does_not_collide_with_tmp_named_sibling() {
        // a sibling cache literally named `a.json.tmp` used to be
        // clobbered by `a.json`'s temp file (with_extension("json.tmp"))
        let dir = std::env::temp_dir().join("fxp_cellcache_tmpname_test");
        let _ = std::fs::remove_dir_all(&dir);
        let a = dir.join("a.json");
        let sibling = dir.join("a.json.tmp");
        let mut cs = CellCache::open(&sibling, "tiny", Regime::Vanilla, 42).unwrap();
        cs.put(&job(W::Bits(4), W::Bits(4)), &CellEval::Na);
        cs.save().unwrap();
        let before = std::fs::read_to_string(&sibling).unwrap();

        let mut ca = CellCache::open(&a, "tiny", Regime::Vanilla, 42).unwrap();
        ca.put(&job(W::Bits(8), W::Bits(8)), &CellEval::Na);
        ca.save().unwrap();
        assert_eq!(std::fs::read_to_string(&sibling).unwrap(), before);
        // and no temp litter is left behind after a clean save
        let litter: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| {
                e.file_name().to_string_lossy().ends_with(".tmp")
                    && e.path() != sibling
            })
            .collect();
        assert!(litter.is_empty(), "{litter:?}");
    }
}
