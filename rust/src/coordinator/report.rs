//! Result persistence: paper-style text reports and JSON dumps that the
//! bench harness and EXPERIMENTS.md consume.

use std::path::Path;

use crate::coordinator::grid::GridResult;
use crate::error::Result;
use crate::util::json::Json;

/// Serialise a grid to JSON (for results/ dumps).
pub fn grid_to_json(g: &GridResult) -> Json {
    let mut rows = Vec::new();
    for row in &g.outcomes {
        for c in row {
            rows.push(Json::obj(vec![
                ("w", Json::Str(c.w.label())),
                ("a", Json::Str(c.a.label())),
                (
                    "top1_err",
                    match &c.eval {
                        Some(e) => Json::Num(e.top1_err),
                        None => Json::Null,
                    },
                ),
                (
                    "top5_err",
                    match &c.eval {
                        Some(e) => Json::Num(e.top5_err),
                        None => Json::Null,
                    },
                ),
                (
                    "loss",
                    match &c.eval {
                        Some(e) => Json::Num(e.mean_loss),
                        None => Json::Null,
                    },
                ),
            ]));
        }
    }
    Json::obj(vec![
        ("table", Json::from(g.regime.table_number())),
        ("regime", Json::from(g.regime.label())),
        ("arch", Json::Str(g.arch.clone())),
        ("cells", Json::Arr(rows)),
    ])
}

/// Write a grid's text + JSON forms under `dir`.
pub fn save_grid(g: &GridResult, dir: impl AsRef<Path>, topk: usize) -> Result<()> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let stem = format!("table{}_{}", g.regime.table_number(), g.arch);
    std::fs::write(dir.join(format!("{stem}.txt")), g.render(topk))?;
    std::fs::write(
        dir.join(format!("{stem}.json")),
        grid_to_json(g).to_string(),
    )?;
    log::info!("wrote {}/{stem}.{{txt,json}}", dir.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::evaluator::EvalResult;
    use crate::coordinator::grid::CellOutcome;
    use crate::coordinator::regimes::Regime;
    use crate::quant::policy::WidthSpec as W;

    fn grid() -> GridResult {
        GridResult {
            regime: Regime::Prop3,
            arch: "tiny".into(),
            w_axis: vec![W::Bits(4), W::Float],
            a_axis: vec![W::Bits(4), W::Float],
            outcomes: vec![
                vec![
                    CellOutcome { w: W::Bits(4), a: W::Bits(4), eval: None },
                    CellOutcome {
                        w: W::Float,
                        a: W::Bits(4),
                        eval: Some(EvalResult {
                            n: 10,
                            top1_err: 0.25,
                            top5_err: 0.05,
                            mean_loss: 1.2,
                        }),
                    },
                ],
                vec![
                    CellOutcome { w: W::Bits(4), a: W::Float, eval: None },
                    CellOutcome { w: W::Float, a: W::Float, eval: None },
                ],
            ],
        }
    }

    #[test]
    fn json_round_trip() {
        let j = grid_to_json(&grid());
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("table").unwrap().as_usize().unwrap(), 6);
        let cells = parsed.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 4);
        assert_eq!(*cells[0].get("top1_err").unwrap(), Json::Null);
        assert!(
            (cells[1].get("top1_err").unwrap().as_f64().unwrap() - 0.25).abs()
                < 1e-12
        );
    }

    #[test]
    fn save_writes_files() {
        let dir = std::env::temp_dir().join("fxp_report_test");
        let _ = std::fs::remove_dir_all(&dir);
        save_grid(&grid(), &dir, 1).unwrap();
        assert!(dir.join("table6_tiny.txt").exists());
        let j = std::fs::read_to_string(dir.join("table6_tiny.json")).unwrap();
        assert!(Json::parse(&j).is_ok());
    }
}
