//! Calibration: collect per-layer statistics and resolve Q-formats.
//!
//! Activation statistics come from the `stats_batch` executable run on
//! the *float* network over a few calibration batches (absmax is maxed,
//! moments averaged); weight statistics are computed host-side from the
//! parameter tensors.  `quant::calib` turns both into fractional lengths.

use crate::data::loader::sequential_batches;
use crate::data::synth::Dataset;
use crate::error::Result;
use crate::model::params::ParamSet;
use crate::quant::calib::LayerStats;
use crate::quant::policy::NetQuant;
use crate::runtime::literal::{to_literal, HostValue};
use crate::runtime::Engine;
use crate::tensor::Tensor;

/// Calibration data for one network.
#[derive(Clone, Debug)]
pub struct CalibData {
    pub a_stats: Vec<LayerStats>,
}

fn vec_lit(v: &[f32]) -> Result<xla::Literal> {
    to_literal(&HostValue::F32(Tensor::from_vec(&[v.len()], v.to_vec())?))
}

/// Run `stats_batch` over up to `batches` calibration batches with
/// quantization disabled and aggregate.
pub fn activation_stats(
    engine: &Engine,
    arch: &str,
    params: &ParamSet,
    data: &Dataset,
    batches: usize,
) -> Result<CalibData> {
    let spec = engine.manifest.arch(arch)?;
    let exe = engine.executable(arch, "stats_batch")?;
    let l = spec.num_layers;
    let float_nq = NetQuant::all_float(l);
    let v = float_nq.vectors();
    let cfg = [
        vec_lit(&v.w_step)?,
        vec_lit(&v.w_lo)?,
        vec_lit(&v.w_hi)?,
        vec_lit(&v.w_en)?,
        vec_lit(&v.a_step)?,
        vec_lit(&v.a_lo)?,
        vec_lit(&v.a_hi)?,
        vec_lit(&v.a_en)?,
    ];
    let param_lits: Vec<xla::Literal> = params
        .tensors
        .iter()
        .map(|t| to_literal(&HostValue::F32(t.clone())))
        .collect::<Result<_>>()?;

    let mut absmax = vec![0f32; l];
    let mut meanabs = vec![0f64; l];
    let mut meansq = vec![0f64; l];
    let mut used = 0usize;
    for (images, _labels, _valid) in sequential_batches(data, spec.eval_batch)?
        .into_iter()
        .take(batches.max(1))
    {
        let x = to_literal(&HostValue::F32(images))?;
        let mut inputs: Vec<&xla::Literal> = Vec::new();
        inputs.extend(param_lits.iter());
        inputs.push(&x);
        inputs.extend(cfg.iter());
        let outs = exe.run_literals(&inputs)?;
        let am = exe.output_host(&outs, "absmax")?.into_f32()?;
        let ma = exe.output_host(&outs, "meanabs")?.into_f32()?;
        let ms = exe.output_host(&outs, "meansq")?.into_f32()?;
        for i in 0..l {
            absmax[i] = absmax[i].max(am.data()[i]);
            meanabs[i] += ma.data()[i] as f64;
            meansq[i] += ms.data()[i] as f64;
        }
        used += 1;
    }
    let a_stats = (0..l)
        .map(|i| LayerStats {
            absmax: absmax[i],
            meanabs: (meanabs[i] / used as f64) as f32,
            meansq: (meansq[i] / used as f64) as f32,
        })
        .collect();
    Ok(CalibData { a_stats })
}
