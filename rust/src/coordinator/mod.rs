//! The coordinator: the paper's *procedure* contribution, in Rust.
//!
//! The AOT executables know one thing: run a training/eval/stats/grads
//! step under whatever per-layer quantization configuration they are
//! handed.  Everything the paper actually proposes -- which layers'
//! activations are fixed point when, which layers' weights update when,
//! what happens after divergence -- is *data* constructed here:
//!
//! * `backend`   -- the engine abstraction: the XLA/PJRT path and the
//!   pure-Rust native training engine (`crate::train`) behind one trait,
//!   selected per run (`--backend {native,xla}`);
//! * `calibrate` -- activation/weight statistics -> per-layer Q-formats
//!   (min-max or the companion paper's SQNR rule);
//! * `trainer`   -- the SGD step loop (XLA literals) plus the
//!   `TrainSession` contract and the shared divergence-detecting run
//!   loop (the paper's "fails to converge" = our `n/a`);
//! * `phases`    -- the Table 1 bottom-to-top schedule of Proposal 3;
//! * `regimes`   -- no-fine-tune / vanilla / Proposals 1-3 as strategies;
//! * `pool`      -- the deterministic work-queue + worker-pool substrate
//!   (panic isolation, per-worker contexts);
//! * `grid`      -- the (weight width x activation width) experiment grid
//!   behind every results table, serial and parallel/sharded/resumable;
//! * `shard`     -- the multi-process/multi-machine layer: advisory file
//!   locks, per-shard cache files, sweep manifests, and the strict
//!   `grid merge` union;
//! * `evaluator` -- held-out top-k error;
//! * `report`    -- paper-style table rendering, JSON result dumps, and
//!   the per-cell sweep cache;
//! * `analytics` -- `fxpnet report`: grid-wide stability aggregation
//!   over caches + stability reports, and learned abort thresholds.

pub mod analytics;
pub mod backend;
pub mod calibrate;
pub mod config;
pub mod evaluator;
pub mod grid;
pub mod mismatch;
pub mod phases;
pub mod pool;
pub mod regimes;
pub mod report;
pub mod shard;
pub mod trainer;

pub use backend::{Backend, BackendSpec, SessionCfg, XlaBackend};
pub use config::RunCfg;
pub use grid::{
    CellJob, CellOutcome, GridResult, GridRunner, ParallelGridRunner,
    SweepOpts, SweepOutcome,
};
pub use regimes::Regime;
pub use shard::{
    FileLock, LockOpts, MergeOutcome, ShardedCache, SweepManifest,
};
pub use trainer::{TrainOutcome, TrainSession, Trainer};
