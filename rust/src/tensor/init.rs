//! Parameter initialisation (mirrors python/compile/model.py:init_params).
//!
//! Weights: He-normal (std = sqrt(2 / fan_in), fan_in = product of all but
//! the last dimension -- correct for both HWIO conv kernels and (in, out)
//! FC matrices).  Biases: zero.

use super::Tensor;
use crate::util::rng::Rng;

/// He-normal weight tensor.
pub fn he_normal(shape: &[usize], rng: &mut Rng) -> Tensor<f32> {
    let fan_in: usize = shape[..shape.len().saturating_sub(1)].iter().product();
    let std = (2.0 / fan_in.max(1) as f64).sqrt() as f32;
    let mut t = Tensor::zeros(shape);
    rng.fill_normal(t.data_mut(), std);
    t
}

/// Zero bias.
pub fn zeros(shape: &[usize]) -> Tensor<f32> {
    Tensor::zeros(shape)
}

/// Initialise a parameter by name convention: "*.b" -> zeros, else He.
pub fn for_param(name: &str, shape: &[usize], rng: &mut Rng) -> Tensor<f32> {
    if name.ends_with(".b") {
        zeros(shape)
    } else {
        he_normal(shape, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn he_std_is_right() {
        let mut rng = Rng::new(0);
        let t = he_normal(&[3, 3, 16, 32], &mut rng);
        let fan_in = 3 * 3 * 16;
        let want = (2.0 / fan_in as f64).sqrt();
        let m = t.mean();
        let var = t
            .data()
            .iter()
            .map(|&x| (x as f64 - m) * (x as f64 - m))
            .sum::<f64>()
            / t.len() as f64;
        assert!(m.abs() < 0.01, "{m}");
        assert!((var.sqrt() - want).abs() / want < 0.1, "{} vs {want}", var.sqrt());
    }

    #[test]
    fn bias_is_zero() {
        let mut rng = Rng::new(0);
        let t = for_param("l3.b", &[64], &mut rng);
        assert!(t.data().iter().all(|&x| x == 0.0));
        let w = for_param("l3.w", &[8, 8], &mut rng);
        assert!(w.data().iter().any(|&x| x != 0.0));
    }

    #[test]
    fn deterministic_given_seed() {
        let a = he_normal(&[4, 4], &mut Rng::new(7));
        let b = he_normal(&[4, 4], &mut Rng::new(7));
        assert_eq!(a.data(), b.data());
    }
}
