//! Host-side dense tensors (row-major, contiguous).
//!
//! These are deliberately simple: the heavy math runs inside the
//! AOT-compiled XLA executables; the host only needs construction,
//! reshuffling, reductions for evaluation, and conversion to/from PJRT
//! literals (rust/src/runtime/literal.rs).

pub mod init;

use crate::error::{FxpError, Result};

/// Dense row-major tensor over a copyable scalar.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor<T: Copy> {
    shape: Vec<usize>,
    data: Vec<T>,
}

pub type TensorF = Tensor<f32>;
pub type TensorI = Tensor<i32>;

impl<T: Copy + Default> Tensor<T> {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![T::default(); n] }
    }
}

impl<T: Copy> Tensor<T> {
    pub fn from_vec(shape: &[usize], data: Vec<T>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(FxpError::shape(format!(
                "shape {:?} needs {} elements, got {}",
                shape,
                n,
                data.len()
            )));
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn full(shape: &[usize], v: T) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn scalar1(v: T) -> Self {
        Tensor { shape: vec![1], data: vec![v] }
    }

    #[inline]
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn data(&self) -> &[T] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<T> {
        self.data
    }

    /// Reinterpret with a new shape (same element count).
    pub fn reshape(mut self, shape: &[usize]) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            return Err(FxpError::shape(format!(
                "cannot reshape {:?} -> {:?}",
                self.shape, shape
            )));
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// Rows `rows[i]` of a 2-D-interpretable tensor (first dim = rows),
    /// gathered into a new tensor; used to assemble shuffled batches.
    pub fn gather_rows(&self, rows: &[usize]) -> Result<Self> {
        if self.shape.is_empty() {
            return Err(FxpError::shape("gather_rows on scalar"));
        }
        let row_len: usize = self.shape[1..].iter().product();
        let n_rows = self.shape[0];
        let mut data = Vec::with_capacity(rows.len() * row_len);
        for &r in rows {
            if r >= n_rows {
                return Err(FxpError::shape(format!(
                    "row {r} out of range {n_rows}"
                )));
            }
            data.extend_from_slice(&self.data[r * row_len..(r + 1) * row_len]);
        }
        let mut shape = self.shape.clone();
        shape[0] = rows.len();
        Ok(Tensor { shape, data })
    }
}

impl Tensor<f32> {
    /// L2 norm.
    pub fn norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Cosine similarity with another tensor of the same shape.
    pub fn cosine(&self, other: &Tensor<f32>) -> Result<f64> {
        if self.shape != other.shape {
            return Err(FxpError::shape("cosine: shape mismatch"));
        }
        let dot: f64 = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| a as f64 * b as f64)
            .sum();
        let na = self.norm();
        let nb = other.norm();
        if na == 0.0 || nb == 0.0 {
            return Ok(0.0);
        }
        Ok(dot / (na * nb))
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().map(|&x| x as f64).sum::<f64>() / self.data.len() as f64
    }

    /// Indices of the k largest values in each row of a (n, m) tensor,
    /// descending; used for top-k error in the evaluator.
    pub fn topk_rows(&self, k: usize) -> Result<Vec<Vec<usize>>> {
        if self.shape.len() != 2 {
            return Err(FxpError::shape("topk_rows wants 2-D"));
        }
        let (n, m) = (self.shape[0], self.shape[1]);
        let k = k.min(m);
        let mut out = Vec::with_capacity(n);
        for r in 0..n {
            let row = &self.data[r * m..(r + 1) * m];
            let mut idx: Vec<usize> = (0..m).collect();
            idx.sort_by(|&a, &b| row[b].partial_cmp(&row[a]).unwrap());
            idx.truncate(k);
            out.push(idx);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_reshape() {
        let t = Tensor::from_vec(&[2, 3], vec![1.0f32, 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(t.shape(), &[2, 3]);
        let t = t.reshape(&[3, 2]).unwrap();
        assert_eq!(t.shape(), &[3, 2]);
        assert!(t.clone().reshape(&[4, 2]).is_err());
        assert!(Tensor::from_vec(&[2, 2], vec![0.0f32; 3]).is_err());
    }

    #[test]
    fn gather_rows() {
        let t = Tensor::from_vec(&[3, 2], vec![0f32, 1., 10., 11., 20., 21.]).unwrap();
        let g = t.gather_rows(&[2, 0]).unwrap();
        assert_eq!(g.shape(), &[2, 2]);
        assert_eq!(g.data(), &[20., 21., 0., 1.]);
        assert!(t.gather_rows(&[5]).is_err());
    }

    #[test]
    fn reductions() {
        let t = Tensor::from_vec(&[4], vec![3.0f32, -4.0, 0.0, 1.0]).unwrap();
        assert!((t.norm() - (26.0f64).sqrt()).abs() < 1e-12);
        assert_eq!(t.abs_max(), 4.0);
        assert_eq!(t.mean(), 0.0);
    }

    #[test]
    fn cosine() {
        let a = Tensor::from_vec(&[3], vec![1.0f32, 0., 0.]).unwrap();
        let b = Tensor::from_vec(&[3], vec![0.0f32, 1., 0.]).unwrap();
        assert_eq!(a.cosine(&b).unwrap(), 0.0);
        assert!((a.cosine(&a).unwrap() - 1.0).abs() < 1e-12);
        let z = Tensor::zeros(&[3]);
        assert_eq!(a.cosine(&z).unwrap(), 0.0);
        let c = Tensor::<f32>::zeros(&[4]);
        assert!(a.cosine(&c).is_err());
    }

    #[test]
    fn topk() {
        let t =
            Tensor::from_vec(&[2, 4], vec![0.1f32, 0.9, 0.5, 0.2, 9., 7., 8., 6.])
                .unwrap();
        let tk = t.topk_rows(2).unwrap();
        assert_eq!(tk[0], vec![1, 2]);
        assert_eq!(tk[1], vec![0, 2]);
        // k larger than row is clamped
        assert_eq!(t.topk_rows(10).unwrap()[0].len(), 4);
    }
}
