//! CLI command implementations (thin orchestration over the library).

use crate::cli::{artifacts_dir, parse_shard, Args};
use crate::cluster;
use crate::coordinator::analytics::Analytics;
use crate::coordinator::backend::{Backend, BackendSpec, SessionCfg};
use crate::coordinator::calibrate;
use crate::coordinator::config::RunCfg;
use crate::coordinator::evaluator::evaluate;
use crate::coordinator::grid::{
    self, GridRunner, ParallelGridRunner, SweepOpts, SweepOutcome,
};
use crate::coordinator::phases;
use crate::coordinator::regimes::Regime;
use crate::coordinator::report;
use crate::coordinator::shard::{self, LockOpts, SweepManifest};
use crate::coordinator::trainer::{
    run_session, run_session_with, upd_all, AbortOverlay, TrainSession,
};
use crate::data::loader::LoaderCfg;
use crate::data::synth::Dataset;
use crate::error::{FxpError, Result};
use crate::fixedpoint::QFormat;
use crate::inference::verify::parity_report;
use crate::inference::FixedPointNet;
use crate::model::checkpoint::{save_params, Checkpoint};
use crate::model::manifest::ArchSpec;
use crate::model::params::ParamSet;
use crate::quant::calib::CalibMethod;
use crate::quant::policy::{NetQuant, WidthSpec};
use crate::runtime::Engine;
use crate::train::telemetry::TelemetryLog;
use crate::util::rng::derive_seed;

/// Run one command; the returned value is the process exit code (the
/// `grid merge --check` coverage contract uses 2 for "incomplete").
pub fn dispatch(args: &Args) -> Result<i32> {
    match args.command.as_str() {
        "pretrain" => args.no_positionals().and_then(|()| pretrain(args)).map(ok),
        "train" => args.no_positionals().and_then(|()| train_cmd(args)).map(ok),
        "grid" => grid_cmd(args),
        "cluster" => cluster_cmd(args),
        "serve" => args.no_positionals().and_then(|()| serve_cmd(args)).map(ok),
        "report" => report_cmd(args).map(ok),
        "perf" => perf_cmd(args),
        "eval" => args.no_positionals().and_then(|()| eval_cmd(args)).map(ok),
        "infer" => args.no_positionals().and_then(|()| infer(args)).map(ok),
        "mismatch" => args.no_positionals().and_then(|()| mismatch(args)).map(ok),
        "table1" => {
            args.no_positionals()?;
            let layers = args.usize_or("layers", 4)?;
            println!("{}", phases::render_table1(layers));
            Ok(0)
        }
        "help" | "--help" | "-h" => {
            println!("{}", super::USAGE);
            Ok(0)
        }
        other => Err(FxpError::config(format!(
            "unknown command '{other}'; try `fxpnet help`"
        ))),
    }
}

fn ok(_: ()) -> i32 {
    0
}

/// All cores (the `--threads` default for single-session commands).
fn all_cores() -> usize {
    std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1)
}

/// Parse the shared run flags.  `threads_default` is the command's
/// `--threads` fallback: whole-machine for single-session commands
/// (pretrain/train/eval/infer), 1 inside grid sweeps whose cells already
/// run in parallel across `--workers`.  Results are bit-identical for
/// any thread count either way.
fn run_cfg(args: &Args, threads_default: usize) -> Result<RunCfg> {
    let d = RunCfg::default();
    let method = match args.get("calib") {
        None => d.method,
        Some(m) => CalibMethod::parse(m)
            .ok_or_else(|| FxpError::config(format!("bad --calib '{m}'")))?,
    };
    Ok(RunCfg {
        lr: args.f32_or("lr", d.lr)?,
        momentum: args.f32_or("momentum", d.momentum)?,
        finetune_steps: args.usize_or("steps", d.finetune_steps)?,
        phase_steps: args.usize_or("phase-steps", d.phase_steps)?,
        seed: args.u64_or("seed", d.seed)?,
        workers: args.usize_or("workers", d.workers)?,
        threads: args.usize_or("threads", threads_default)?.max(1),
        topk: args.usize_or("topk", d.topk)?,
        max_loss: args.f32_or("max-loss", d.max_loss)?,
        early_abort: !args.has("no-early-abort"),
        abort_overlay: args
            .get("abort-policy")
            .map(AbortOverlay::load)
            .transpose()?,
        method,
        ..d
    })
}

/// Resolve `--backend`: explicit flag wins; otherwise XLA when the
/// artifact directory exists, native for the offline build.
fn backend_spec(args: &Args) -> Result<BackendSpec> {
    let artifacts = artifacts_dir(args);
    match args.get("backend") {
        None => Ok(BackendSpec::auto(&artifacts)),
        Some(s) => BackendSpec::parse(s, &artifacts),
    }
}

fn datasets(args: &Args, spec: &ArchSpec) -> Result<(Dataset, Dataset)> {
    let (h, w) = (spec.input[0], spec.input[1]);
    let train_n = args.usize_or("train-n", 8192)?;
    let eval_n = args.usize_or("eval-n", 2048)?;
    let seed = args.u64_or("seed", 42)?;
    log::info!("generating SynthShapes: train={train_n} eval={eval_n} ({h}x{w})");
    // disjoint streams for train/eval
    Ok((
        Dataset::generate(train_n, h, w, seed.wrapping_mul(2).wrapping_add(1)),
        Dataset::generate(eval_n, h, w, seed.wrapping_mul(2)),
    ))
}

fn load_ckpt(args: &Args, spec: &ArchSpec) -> Result<ParamSet> {
    let path = args.require("ckpt")?;
    let ck = Checkpoint::load(path)?;
    ck.check_matches(&spec.name, &spec.params)?;
    log::info!("loaded checkpoint {path} (step {})", ck.step);
    Ok(ck.params)
}

/// The base parameters a command starts from: `--ckpt` when given; with
/// the native backend a fresh deterministic He init from `--seed` is an
/// accepted substitute (CI sweeps need no checkpoint file).
fn base_params(
    args: &Args,
    spec: &ArchSpec,
    backend: &dyn Backend,
    seed: u64,
) -> Result<ParamSet> {
    if args.get("ckpt").is_some() {
        return load_ckpt(args, spec);
    }
    if backend.supports_fresh_init() {
        log::info!("no --ckpt: fresh He init from seed {seed}");
        return Ok(ParamSet::init(spec, derive_seed(seed, "base-init", &[])));
    }
    Err(FxpError::config(format!(
        "missing required flag --ckpt (the {} backend cannot start from \
         a fresh init)",
        backend.name()
    )))
}

fn width(args: &Args, key: &str) -> Result<WidthSpec> {
    let v = args.require(key)?;
    WidthSpec::parse(v)
        .ok_or_else(|| FxpError::config(format!("bad --{key} '{v}'")))
}

/// `fxpnet pretrain`: float baseline training with step-decay lr, on
/// either backend.
fn pretrain(args: &Args) -> Result<()> {
    let arch = args.get_or("arch", "paper12");
    let cfg = run_cfg(args, all_cores())?;
    let backend = backend_spec(args)?.build_with_threads(cfg.threads)?;
    let spec = backend.arch(&arch)?;
    let steps = args.usize_or("steps", 800)?;
    let lr = args.f32_or("lr", 0.05)?;
    let out = args.get_or("out", &format!("{arch}_float.ckpt"));
    let (train, eval_set) = datasets(args, &spec)?;

    // --from CKPT continues training from a checkpoint (e.g. when a run's
    // saddle escape happened near the end of its step budget)
    let params = match args.get("from") {
        Some(path) => {
            let ck = Checkpoint::load(path)?;
            ck.check_matches(&arch, &spec.params)?;
            log::info!("continuing from {path} (step {})", ck.step);
            ck.params
        }
        None => ParamSet::init(&spec, cfg.seed),
    };
    log::info!(
        "pretraining {arch} ({} backend): {} params, {} steps, lr {lr}",
        backend.name(),
        params.num_scalars(),
        steps
    );
    let nq = NetQuant::all_float(spec.num_layers);
    let mut tr = backend.new_session(SessionCfg {
        arch: &arch,
        params: &params,
        nq: &nq,
        upd: &upd_all(spec.num_layers),
        lr,
        momentum: cfg.momentum,
        data: train,
        loader: LoaderCfg {
            batch: spec.train_batch,
            augment: true,
            max_shift: 2,
            seed: cfg.seed,
        },
        max_loss: cfg.max_loss,
        seed: derive_seed(cfg.seed, "sgd-round", &[0]),
        threads: cfg.threads,
    })?;
    // two-stage decay at 60% and 85%
    let s1 = steps * 3 / 5;
    let s2 = steps * 17 / 20;
    let mut last = 0.0f32;
    for (stage, (n, stage_lr)) in [
        (s1, lr),
        (s2 - s1, lr * 0.2),
        (steps - s2, lr * 0.04),
    ]
    .iter()
    .enumerate()
    {
        if stage > 0 {
            tr.set_config(&nq, &upd_all(spec.num_layers), *stage_lr, cfg.momentum)?;
        }
        let outc = run_session(&mut *tr, *n, 20)?;
        if outc.diverged {
            return Err(FxpError::Diverged {
                step: tr.global_step(),
                loss: outc.final_loss().unwrap_or(f32::NAN),
            });
        }
        for (s, l) in &outc.history {
            log::info!("step {s:>5}  loss {l:.4}");
        }
        last = outc.final_loss().unwrap_or(last);
    }
    let tuned = tr.params()?;
    let ev = backend.evaluate(&arch, &tuned, &nq, &eval_set)?;
    log::info!("pretrained: final loss {last:.4}; float eval: {ev}");
    save_params(&out, &arch, tr.global_step() as u64, &tuned)?;
    println!(
        "pretrained {arch}: {} steps, float top-1 error {:.2}%, saved {out}",
        tr.global_step(),
        ev.top1_err * 100.0
    );
    Ok(())
}

/// `fxpnet train`: one fine-tuning run at a single (w, a) cell with the
/// convergence verdict on stdout -- the native engine's CI gate
/// (`--gate` turns "did not improve" into a non-zero exit).
fn train_cmd(args: &Args) -> Result<()> {
    let arch = args.get_or("arch", "tiny");
    let cfg = run_cfg(args, all_cores())?;
    let backend = backend_spec(args)?.build_with_threads(cfg.threads)?;
    let spec = backend.arch(&arch)?;
    let steps = args.usize_or("steps", 100)?;
    let (train, eval_set) = datasets(args, &spec)?;
    let params = base_params(args, &spec, backend.as_ref(), cfg.seed)?;
    let w = WidthSpec::parse(&args.get_or("w", "8"))
        .ok_or_else(|| FxpError::config("bad --w"))?;
    let a = WidthSpec::parse(&args.get_or("a", "8"))
        .ok_or_else(|| FxpError::config("bad --a"))?;
    let a_stats =
        backend.activation_stats(&arch, &params, &train, cfg.calib_batches)?;
    let nq =
        NetQuant::for_cell(w, a, &params.weight_stats(), &a_stats, cfg.method)?;
    log::info!(
        "training {arch} ({} backend) at w={} a={} for {steps} steps, \
         {} threads",
        backend.name(),
        w.label(),
        a.label(),
        cfg.threads
    );
    let mut tr = backend.new_session(SessionCfg {
        arch: &arch,
        params: &params,
        nq: &nq,
        upd: &upd_all(spec.num_layers),
        lr: cfg.lr,
        momentum: cfg.momentum,
        data: train,
        loader: LoaderCfg {
            batch: spec.train_batch,
            augment: cfg.augment,
            max_shift: 2,
            seed: cfg.seed,
        },
        max_loss: cfg.max_loss,
        seed: derive_seed(cfg.seed, "sgd-round", &[1]),
        threads: cfg.threads,
    })?;
    // a single-cell train run is a vanilla fine-tune: an --abort-policy
    // overlay's "vanilla" entry applies here, like a vanilla-regime cell
    let policy = cfg.abort_policy("vanilla");
    let mut sink = args.get("stability-report").map(|_| TelemetryLog::default());
    let outc = run_session_with(
        &mut *tr,
        steps,
        (steps / 20).max(1),
        policy.as_ref(),
        sink.as_mut(),
    )?;
    // the telemetry stream is written even for runs that diverge or
    // abort -- those are exactly the runs worth inspecting
    if let (Some(path), Some(tlog)) = (args.get("stability-report"), &sink) {
        let wrapped = crate::util::json::Json::obj(vec![
            (
                "report_version",
                crate::util::json::Json::from(report::REPORT_VERSION),
            ),
            ("kind", crate::util::json::Json::Str("train-telemetry".into())),
            ("steps", tlog.to_json()),
        ]);
        std::fs::write(path, wrapped.to_string())?;
        println!("wrote stability report {path} ({} steps)", tlog.len());
    }
    for (s, l) in &outc.history {
        println!("step {s:>5}  loss {l:.4}");
    }
    let initial = outc.history.first().map(|&(_, l)| l).unwrap_or(f32::NAN);
    let final_loss = outc.final_loss().unwrap_or(f32::NAN);
    if outc.diverged {
        if let Some((reason, step)) = outc.aborted {
            eprintln!("aborted early at step {step}: {}", reason.as_str());
        }
        // like pretrain: never persist a blown-up net
        return Err(FxpError::Diverged {
            step: tr.global_step(),
            loss: final_loss,
        });
    }
    let tuned = tr.params()?;
    if let Some(out) = args.get("out") {
        save_params(out, &arch, tr.global_step() as u64, &tuned)?;
        println!("saved {out}");
    }
    let ev = backend.evaluate(&arch, &tuned, &nq, &eval_set)?;
    println!(
        "trained {arch} w={} a={}: loss {initial:.4} -> {final_loss:.4} over \
         {} steps; eval {ev}",
        w.label(),
        a.label(),
        outc.steps
    );
    let improved = final_loss < initial;
    if args.has("gate") && !improved {
        return Err(FxpError::config(format!(
            "train gate failed: final loss {final_loss:.4} did not improve \
             on initial {initial:.4}"
        )));
    }
    Ok(())
}

/// `fxpnet grid [plan|merge]`: subcommand routing.
fn grid_cmd(args: &Args) -> Result<i32> {
    match args.positionals().first().map(String::as_str) {
        None => grid_run(args).map(ok),
        Some("plan") => grid_plan(args).map(ok),
        Some("merge") => grid_merge(args),
        Some(other) => Err(FxpError::config(format!(
            "unknown grid subcommand '{other}'; try `fxpnet grid plan` or \
             `fxpnet grid merge`"
        ))),
    }
}

/// The cell-cache / sharding options shared by the real and synthetic
/// sweep paths.
fn sweep_opts(
    args: &Args,
    cfg: &RunCfg,
    regime: Regime,
    arch: &str,
    out_dir: &str,
) -> Result<SweepOpts> {
    let shard = match args.get("shard") {
        None => None,
        Some(s) => Some(parse_shard(s)?),
    };
    let resume = args.has("resume");
    let split_cache = args.has("shard-cache");
    if split_cache && shard.is_none() {
        return Err(FxpError::config("--shard-cache needs --shard I/N"));
    }
    let cache_path = args.get("cache").map(std::path::PathBuf::from).or_else(|| {
        (resume || shard.is_some()).then(|| {
            std::path::Path::new(out_dir)
                .join(format!("cache_table{}_{arch}.json", regime.table_number()))
        })
    });
    Ok(SweepOpts {
        workers: cfg.workers,
        shard,
        cache_path,
        resume,
        split_cache,
        lock: LockOpts {
            wait: std::time::Duration::from_secs_f64(
                (args.f32_or("lock-wait", 10.0)? as f64).max(0.0),
            ),
            ..Default::default()
        },
    })
}

/// Print a finished sweep, persist the table when it is final, and
/// explain what remains when it is not.  `stability` writes the per-cell
/// stability report (always, even for partial sweeps -- a shard's report
/// covers its own cells).
fn finish_sweep(
    sweep: &SweepOutcome,
    base_seed: u64,
    out_dir: &str,
    topk: usize,
    stability: Option<&str>,
) -> Result<()> {
    println!("{}", sweep.grid.render(topk));
    if let Some(path) = stability {
        report::save_stability_report(
            &sweep.grid.arch,
            sweep.grid.regime,
            base_seed,
            &sweep.cells,
            &sweep.telemetry,
            path,
        )?;
        println!("wrote stability report {path}");
    }
    log::info!(
        "sweep: {} computed ({} failed -> n/a), {} cached, {} missing, \
         {} workers",
        sweep.computed,
        sweep.failed,
        sweep.cached,
        sweep.missing,
        sweep.pool.workers
    );
    if sweep.is_complete() {
        report::save_grid(&sweep.grid, out_dir, topk)?;
    } else {
        println!(
            "partial sweep: {} cells belong to other shards; with a shared \
             --cache the final shard prints the full table, with \
             --shard-cache combine the shard files via `fxpnet grid merge`",
            sweep.missing
        );
    }
    Ok(())
}

/// `fxpnet grid`: run one regime's full grid (one paper table) through
/// the parallel sweep engine -- `--workers`, `--shard I/N`, `--resume`,
/// `--cache` and `--shard-cache` control execution; results are
/// bit-identical for any worker count / shard layout (the per-cell seed
/// tree keys every stochastic stream by cell identity, not by
/// scheduling).
fn grid_run(args: &Args) -> Result<()> {
    let arch = args.get_or("arch", "paper12");
    let regime_s = args.require("regime")?;
    let regime = Regime::parse(regime_s)
        .ok_or_else(|| FxpError::config(format!("bad --regime '{regime_s}'")))?;
    // --threads defaults to 1 here: cells already run in parallel
    // across --workers, and results are bit-identical either way
    let cfg = run_cfg(args, 1)?;
    let out_dir = args.get_or("out", "results");
    let opts = sweep_opts(args, &cfg, regime, &arch, &out_dir)?;

    // --synthetic: the deterministic engine-free executor -- exercises
    // the whole sweep/shard/cache/merge machinery without artifacts, an
    // XLA runtime, or a checkpoint (a fast mode for plumbing tests)
    if args.has("synthetic") {
        let sweep = grid::run_sweep_with(
            regime,
            &arch,
            cfg.seed,
            &opts,
            |_wid| Ok(()),
            |_, job| grid::synthetic_cell(job),
        )?;
        return finish_sweep(
            &sweep,
            cfg.seed,
            &out_dir,
            cfg.topk,
            args.get("stability-report"),
        );
    }

    let spec = backend_spec(args)?;
    let backend = spec.build_with_threads(cfg.threads)?;
    let arch_spec = backend.arch(&arch)?;
    let base = base_params(args, &arch_spec, backend.as_ref(), cfg.seed)?;
    let (train, eval_set) = datasets(args, &arch_spec)?;
    let a_stats =
        backend.activation_stats(&arch, &base, &train, cfg.calib_batches)?;
    log::info!("grid sweep on the {} backend", backend.name());

    // serial fast path: one shared backend (compile each executable once)
    if cfg.workers == 1 && opts.shard.is_none() && opts.cache_path.is_none() {
        let mut runner = GridRunner::new(
            backend.as_ref(),
            &arch,
            base,
            a_stats,
            train,
            eval_set,
            cfg.clone(),
        );
        let (result, telemetry) = runner.run_grid_full(regime)?;
        println!("{}", result.render(cfg.topk));
        if let Some(path) = args.get("stability-report") {
            report::save_stability_report(
                &result.arch,
                result.regime,
                cfg.seed,
                &report::grid_cells(&result),
                &telemetry,
                path,
            )?;
            println!("wrote stability report {path}");
        }
        report::save_grid(&result, out_dir, cfg.topk)?;
        return Ok(());
    }

    drop(backend); // each worker builds its own backend instance
    let runner = ParallelGridRunner {
        backend: spec,
        arch: arch.clone(),
        base,
        a_stats,
        train_data: train,
        eval_data: eval_set,
        cfg: cfg.clone(),
    };
    let sweep = runner.run_sweep(regime, &opts)?;
    finish_sweep(
        &sweep,
        cfg.seed,
        &out_dir,
        cfg.topk,
        args.get("stability-report"),
    )
}

/// `fxpnet grid plan`: print/write the sweep manifest and per-shard
/// cell lists, so an external scheduler can launch one `fxpnet grid
/// --shard I/N --shard-cache` job per shard and `merge` can later
/// verify the result partition.
fn grid_plan(args: &Args) -> Result<()> {
    if args.positionals().len() > 1 {
        return Err(FxpError::config(format!(
            "unexpected argument '{}'",
            args.positionals()[1]
        )));
    }
    let regime_s = args.require("regime")?;
    let regime = Regime::parse(regime_s)
        .ok_or_else(|| FxpError::config(format!("bad --regime '{regime_s}'")))?;
    let arch = args.get_or("arch", "paper12");
    let seed = args.u64_or("seed", RunCfg::default().seed)?;
    let shards = args.usize_or("shards", 1)?;
    let manifest = SweepManifest::new(&arch, regime, seed, shards)?;
    print!("{}", manifest.render());
    // NOT --out: that means "results directory" everywhere else in the
    // grid family, while this is a single file (merge reads it back
    // with the same --manifest flag)
    if let Some(path) = args.get("manifest") {
        manifest.save(path)?;
        println!("wrote manifest {path}");
    }
    Ok(())
}

/// `fxpnet grid merge <out> <in>...`: union per-shard cell caches into
/// one whole-sweep cache without re-running anything.  Exit code
/// contract under `--check`: 0 = complete sweep, 2 = incomplete (the
/// missing cells are listed on stderr), so CI and cluster scripts can
/// gate on coverage without parsing text.
fn grid_merge(args: &Args) -> Result<i32> {
    let pos = args.positionals();
    if pos.len() < 3 {
        return Err(FxpError::config(
            "usage: fxpnet grid merge <out.json> <in.json>... \
             [--manifest F] [--render] [--topk K] [--check] [--prune]",
        ));
    }
    let out = std::path::PathBuf::from(&pos[1]);
    let inputs: Vec<std::path::PathBuf> =
        pos[2..].iter().map(std::path::PathBuf::from).collect();
    if inputs.contains(&out) {
        return Err(FxpError::config(format!(
            "merge output {} is also an input; refusing to overwrite a \
             shard cache (the first positional is the output path)",
            out.display()
        )));
    }
    let manifest = match args.get("manifest") {
        Some(p) => Some(SweepManifest::load(p)?),
        None => None,
    };
    let merged = shard::merge_files(&inputs, manifest.as_ref())?;
    merged.save(&out)?;
    // summary on stderr: --render's stdout must be exactly the table
    // (byte-comparable against save_grid's .txt output)
    eprintln!("{} -> {}", merged.summary(), out.display());
    if args.has("render") {
        let topk = args.usize_or("topk", 1)?;
        print!("{}", merged.to_grid().render(topk));
    }
    if let Some(path) = args.get("stability-report") {
        // merged shard caches carry no telemetry digests (the cache
        // schema is status-only); the per-shard stability reports are the
        // telemetry-bearing inputs for `fxpnet report`
        report::save_stability_report(
            &merged.arch,
            merged.regime,
            merged.base_seed,
            &merged.cells,
            &std::collections::BTreeMap::new(),
            path,
        )?;
        eprintln!("wrote stability report {path}");
    }
    if args.has("check") && !merged.is_complete() {
        eprintln!("incomplete sweep: {} cells missing:", merged.missing.len());
        for key in &merged.missing {
            eprintln!("  {key}");
        }
        if args.has("prune") {
            eprintln!("not pruning shard caches (sweep incomplete)");
        }
        return Ok(2);
    }
    if args.has("prune") {
        // strict refusal on incomplete coverage lives in
        // prune_shard_inputs, so --prune without --check cannot delete
        // the only copy of a partial sweep either
        let removed = shard::prune_shard_inputs(&merged)?;
        eprintln!("pruned {} superseded shard cache file(s)", removed.len());
    }
    Ok(0)
}

/// `fxpnet cluster {coordinator|worker}`: subcommand routing.
fn cluster_cmd(args: &Args) -> Result<i32> {
    if args.positionals().len() > 1 {
        return Err(FxpError::config(format!(
            "unexpected argument '{}'",
            args.positionals()[1]
        )));
    }
    match args.positionals().first().map(String::as_str) {
        Some("coordinator") => cluster_coordinator(args),
        Some("worker") => cluster_worker(args).map(ok),
        other => Err(FxpError::config(format!(
            "cluster needs a role: `fxpnet cluster coordinator` or \
             `fxpnet cluster worker`{}",
            other.map(|o| format!(" (got '{o}')")).unwrap_or_default()
        ))),
    }
}

/// The regime/config/fingerprint triple both cluster roles derive from
/// their own flags; the handshake compares the fingerprints so a
/// mis-flagged worker is rejected instead of poisoning the sweep.
fn cluster_sweep(args: &Args) -> Result<(Regime, String, RunCfg, u64)> {
    let regime_s = args.require("regime")?;
    let regime = Regime::parse(regime_s)
        .ok_or_else(|| FxpError::config(format!("bad --regime '{regime_s}'")))?;
    let arch = args.get_or("arch", "paper12");
    // threads default 1: workers run one cell at a time but machines
    // often run several worker processes; raise --threads explicitly
    // for one-worker-per-machine pools (results are bit-identical)
    let cfg = run_cfg(args, 1)?;
    let fp = cluster::sweep_fingerprint(
        &arch,
        regime,
        cfg.seed,
        args.has("synthetic"),
        &cfg,
    );
    Ok((regime, arch, cfg, fp))
}

/// `fxpnet cluster coordinator`: serve one regime's grid to TCP
/// workers; write the same cache/table artifacts as `fxpnet grid`.
/// Exit 0 = complete, 2 = drained (SIGTERM/ctrl-C) before completion.
fn cluster_coordinator(args: &Args) -> Result<i32> {
    let (regime, arch, cfg, fp) = cluster_sweep(args)?;
    let out_dir = args.get_or("out", "results");
    let cache_path = args
        .get("cache")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| {
            std::path::Path::new(&out_dir)
                .join(format!("cache_table{}_{arch}.json", regime.table_number()))
        });
    if let Some(dir) = cache_path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let interval = args.u64_or("heartbeat-ms", 1000)?;
    let deadline = args.u64_or("deadline-ms", 5000)?;
    if deadline <= interval {
        return Err(FxpError::config(format!(
            "--deadline-ms {deadline} must exceed --heartbeat-ms {interval} \
             (several intervals of slack, or one lost beat kills a worker)"
        )));
    }
    let opts = cluster::ClusterOpts {
        listen: args.get_or("listen", "127.0.0.1:0"),
        port_file: args.get("port-file").map(std::path::PathBuf::from),
        hb: cluster::HeartbeatCfg {
            interval: std::time::Duration::from_millis(interval),
            deadline: std::time::Duration::from_millis(deadline),
        },
        retry_cap: args.usize_or("retry-cap", 5)?.max(1),
        backoff_base: std::time::Duration::from_millis(
            args.u64_or("backoff-ms", 100)?,
        ),
        summary_path: args.get("summary").map(std::path::PathBuf::from),
        cache_path,
        lock: LockOpts {
            wait: std::time::Duration::from_secs_f64(
                (args.f32_or("lock-wait", 10.0)? as f64).max(0.0),
            ),
            ..Default::default()
        },
    };
    let shutdown = cluster::install_drain_handler();
    let outcome =
        cluster::run_coordinator(regime, &arch, cfg.seed, fp, &opts, shutdown)?;
    println!("{}", outcome.grid.render(cfg.topk));
    let s = &outcome.summary;
    log::info!(
        "cluster sweep: {} computed, {} cached, {} redispatched, \
         {} duplicates, {} worker deaths, {} handshakes",
        s.computed,
        s.cached,
        s.redispatched,
        s.duplicates,
        s.worker_deaths,
        s.workers
    );
    if let Some(path) = args.get("stability-report") {
        report::save_stability_report(
            &arch,
            regime,
            cfg.seed,
            &outcome.cells,
            &outcome.telemetry,
            path,
        )?;
        println!("wrote stability report {path}");
    }
    if s.complete {
        report::save_grid(&outcome.grid, &out_dir, cfg.topk)?;
        Ok(0)
    } else {
        println!(
            "drained before completion: {} of {} cells done; restart the \
             coordinator with the same --cache to resume",
            s.computed + s.cached,
            s.cells
        );
        Ok(2)
    }
}

/// The real-backend cell executor for cluster workers: wraps
/// [`ParallelGridRunner::run_cell_job`], memoizing (and disk-caching)
/// the per-width float-activation seed nets across the worker's life.
struct BackendExec {
    runner: ParallelGridRunner,
    backend: Box<dyn Backend>,
    p1: std::collections::HashMap<String, Option<ParamSet>>,
    p1_dir: Option<std::path::PathBuf>,
}

impl cluster::CellExec for BackendExec {
    fn run(
        &mut self,
        job: &grid::CellJob,
    ) -> Result<(
        crate::coordinator::regimes::CellResult,
        Option<crate::train::telemetry::TelemetrySummary>,
    )> {
        self.runner.run_cell_job_full(
            self.backend.as_ref(),
            &mut self.p1,
            self.p1_dir.as_deref(),
            job,
        )
    }
}

/// Resolve the coordinator address: `--connect H:P` directly, or
/// `--port-file F` polled until the coordinator writes it (the
/// rendezvous for `--listen 127.0.0.1:0`).
fn cluster_connect(args: &Args) -> Result<String> {
    if let Some(c) = args.get("connect") {
        return Ok(c.to_string());
    }
    let Some(pf) = args.get("port-file") else {
        return Err(FxpError::config(
            "need --connect H:P or --port-file F to reach the server",
        ));
    };
    let wait = std::time::Duration::from_secs(args.u64_or("port-wait", 30)?);
    let start = std::time::Instant::now();
    loop {
        match std::fs::read_to_string(pf) {
            Ok(s) if !s.trim().is_empty() => return Ok(s.trim().to_string()),
            _ if start.elapsed() > wait => {
                return Err(FxpError::config(format!(
                    "--port-file {pf}: no coordinator address after \
                     {}s; is the coordinator running?",
                    wait.as_secs()
                )));
            }
            _ => std::thread::sleep(std::time::Duration::from_millis(50)),
        }
    }
}

/// `fxpnet cluster worker`: pull cells from a coordinator until
/// drained.  Sweep flags must match the coordinator's (fingerprint
/// handshake); `--inject` arms deterministic fault injection.
fn cluster_worker(args: &Args) -> Result<()> {
    let (regime, arch, cfg, fp) = cluster_sweep(args)?;
    let d = cluster::WorkerOpts::default();
    let wopts = cluster::WorkerOpts {
        connect: cluster_connect(args)?,
        name: args.get_or("name", &d.name),
        shard: args.get("shard").map(parse_shard).transpose()?,
        fault: args
            .get("inject")
            .map(cluster::FaultSpec::parse)
            .transpose()?
            .unwrap_or_default(),
        reconnect_cap: args.usize_or("reconnect", d.reconnect_cap)?,
        reconnect_backoff: std::time::Duration::from_millis(
            args.u64_or("reconnect-backoff-ms", 200)?,
        ),
        connect_timeout: std::time::Duration::from_millis(
            args.u64_or("connect-timeout-ms", 10_000)?.max(1),
        ),
    };
    log::info!(
        "cluster worker {} -> {} (regime {}, fingerprint {fp:016x})",
        wopts.name,
        wopts.connect,
        regime.label()
    );
    let report = if args.has("synthetic") {
        cluster::run_worker(regime, cfg.seed, fp, &mut cluster::SyntheticExec, &wopts)?
    } else {
        let spec = backend_spec(args)?;
        let backend = spec.build_with_threads(cfg.threads)?;
        let arch_spec = backend.arch(&arch)?;
        let base = base_params(args, &arch_spec, backend.as_ref(), cfg.seed)?;
        let (train, eval_set) = datasets(args, &arch_spec)?;
        let a_stats =
            backend.activation_stats(&arch, &base, &train, cfg.calib_batches)?;
        // seed nets are disk-cached next to the sweep's artifacts so
        // workers (and grid runs) share the retraining work
        let out_dir = args.get_or("out", "results");
        std::fs::create_dir_all(&out_dir)?;
        let mut exec = BackendExec {
            runner: ParallelGridRunner {
                backend: spec,
                arch: arch.clone(),
                base,
                a_stats,
                train_data: train,
                eval_data: eval_set,
                cfg: cfg.clone(),
            },
            backend,
            p1: std::collections::HashMap::new(),
            p1_dir: Some(std::path::PathBuf::from(out_dir)),
        };
        cluster::run_worker(regime, cfg.seed, fp, &mut exec, &wopts)?
    };
    println!(
        "worker {}: computed {} cells, delivered {}, {} reconnects; sweep \
         complete: {}",
        wopts.name,
        report.computed,
        report.delivered,
        report.reconnects,
        report.sweep_complete
    );
    Ok(())
}

/// `fxpnet report <input.json>...`: grid-wide stability analytics.
/// Inputs are merged v4 cell caches and/or v2 per-cell stability
/// reports, auto-detected per file; the output (table and `--json`
/// bytes) is a pure function of the union of cells, so any shard split
/// / thread count / grid-vs-cluster provenance covering the same sweeps
/// produces byte-identical analytics.
fn report_cmd(args: &Args) -> Result<()> {
    let pos = args.positionals();
    if pos.is_empty() {
        return Err(FxpError::config(
            "usage: fxpnet report <cache.json|stability.json>... \
             [--json F] [--suggest-thresholds F]",
        ));
    }
    let mut analytics = Analytics::new();
    for p in pos {
        analytics.ingest_file(p)?;
    }
    print!("{}", analytics.render());
    if let Some(path) = args.get("json") {
        std::fs::write(path, analytics.to_json().to_string())?;
        eprintln!("wrote analytics JSON {path}");
    }
    if let Some(path) = args.get("suggest-thresholds") {
        let overlay = analytics.suggest_thresholds();
        std::fs::write(path, overlay.to_json().to_string())?;
        eprintln!(
            "wrote learned abort-policy overlay {path} ({} regime \
             entr{})",
            overlay.regimes.len(),
            if overlay.regimes.len() == 1 { "y" } else { "ies" }
        );
    }
    Ok(())
}

/// One `fxpnet perf` comparison appended to the gate table; a violation
/// is also pushed onto `violations` for the final error listing.
fn perf_gate(
    table: &mut crate::bench::Table,
    violations: &mut Vec<String>,
    file: &str,
    name: &str,
    measured: f64,
    bound: f64,
    ceiling: bool,
) {
    let ok = if ceiling { measured <= bound } else { measured >= bound };
    table.row(vec![
        file.to_string(),
        name.to_string(),
        format!("{measured:.3}"),
        format!("{} {bound:.3}", if ceiling { "<=" } else { ">=" }),
        if ok { "ok" } else { "FAIL" }.to_string(),
    ]);
    if !ok {
        violations.push(format!(
            "{file}: {name} = {measured:.3} violates the baseline \
             {} {bound:.3}",
            if ceiling { "cap" } else { "floor" }
        ));
    }
}

/// `fxpnet perf <BENCH.json>...`: the consolidated perf-trajectory
/// gate.  Each measured report (`BENCH_engine.json`,
/// `BENCH_train.json`, `BENCH_serve.json`) is diffed against the
/// committed ratio floors in `--baseline` (default
/// `BENCH_baseline.json`); every comparison lands in one table, and any
/// violation names its key and exits non-zero.  Baseline sections or
/// measured keys that are absent are skipped with a note (e.g. the
/// threaded-step gate on a single-core host).
fn perf_cmd(args: &Args) -> Result<i32> {
    use crate::util::json::Json;
    let pos = args.positionals();
    if pos.is_empty() {
        return Err(FxpError::config(
            "usage: fxpnet perf <BENCH.json>... [--baseline F]",
        ));
    }
    let baseline_path = args.get_or("baseline", "BENCH_baseline.json");
    let baseline = Json::parse(&std::fs::read_to_string(&baseline_path).map_err(
        |e| FxpError::config(format!("--baseline {baseline_path}: {e}")),
    )?)?;
    // a floor from `--baseline`, or None (skip + note) when the section
    // or key is not committed
    let bound = |section: &str, key: &str| -> Option<f64> {
        match baseline.opt(section).map(|s| s.get(key).and_then(Json::as_f64)) {
            Some(Ok(v)) => Some(v),
            Some(Err(_)) | None => {
                eprintln!(
                    "note: baseline has no {section}.{key}; gate skipped"
                );
                None
            }
        }
    };
    let mut table = crate::bench::Table::new(
        "perf-trajectory gates (measured ratios vs committed baseline)",
        &["report", "gate", "measured", "bound", "verdict"],
    );
    let mut violations = Vec::new();
    for p in pos {
        let j = Json::parse(&std::fs::read_to_string(p).map_err(|e| {
            FxpError::config(format!("perf input {p}: {e}"))
        })?)?;
        let kind = j
            .opt("bench")
            .map(|b| b.as_str())
            .transpose()?
            .map(str::to_string)
            .or_else(|| j.opt("gates").map(|_| "serve".to_string()));
        match kind.as_deref() {
            Some("engine_throughput") => {
                let isa = j.get("kernel_isa")?.as_str()?.to_string();
                let key = if isa == "scalar" {
                    "min_speedup_gemm_1t"
                } else {
                    "min_speedup_gemm_1t_simd"
                };
                if let Some(b) = bound("engine_throughput", key) {
                    let m = j.get("speedup_gemm_1t")?.as_f64()?;
                    perf_gate(&mut table, &mut violations, p, key, m, b, false);
                }
                if isa != "scalar" {
                    if let Some(b) = bound("engine_throughput", "min_simd_speedup_q8") {
                        let m = j.get("simd_speedup_q8")?.as_f64()?;
                        perf_gate(
                            &mut table,
                            &mut violations,
                            p,
                            "min_simd_speedup_q8",
                            m,
                            b,
                            false,
                        );
                    }
                }
            }
            Some("train_throughput") => {
                let isa = j.get("kernel_isa")?.as_str()?.to_string();
                if j.get("threads")?.as_usize()? > 1 {
                    if let Some(b) = bound("train_throughput", "min_threaded_step_speedup") {
                        let m = j.get("speedup_threaded")?.as_f64()?;
                        perf_gate(
                            &mut table,
                            &mut violations,
                            p,
                            "min_threaded_step_speedup",
                            m,
                            b,
                            false,
                        );
                    }
                } else {
                    eprintln!(
                        "note: {p}: single-threaded run; \
                         min_threaded_step_speedup gate skipped"
                    );
                }
                if isa != "scalar" {
                    if let Some(b) = bound("train_throughput", "min_simd_step_speedup") {
                        let m = j.get("simd_step_speedup")?.as_f64()?;
                        perf_gate(
                            &mut table,
                            &mut violations,
                            p,
                            "min_simd_step_speedup",
                            m,
                            b,
                            false,
                        );
                    }
                }
            }
            Some("serve") => {
                let gates = j.get("gates")?;
                for (measured_key, bound_key, ceiling) in [
                    ("p95_ratio_uniform", "max_p95_ratio_uniform", true),
                    ("throughput_ratio_bursty", "min_throughput_ratio_bursty", false),
                ] {
                    let Some(m) = gates.opt(measured_key) else {
                        eprintln!(
                            "note: {p}: no measured {measured_key} (trace \
                             not replayed); gate skipped"
                        );
                        continue;
                    };
                    if let Some(b) = bound("serve", bound_key) {
                        perf_gate(
                            &mut table,
                            &mut violations,
                            p,
                            bound_key,
                            m.as_f64()?,
                            b,
                            ceiling,
                        );
                    }
                }
            }
            _ => {
                return Err(FxpError::config(format!(
                    "perf input {p} is not a recognized bench report \
                     (expected a 'bench' key of engine_throughput / \
                     train_throughput, or a serve report with 'gates')"
                )));
            }
        }
    }
    print!("{}", table.render());
    if violations.is_empty() {
        Ok(0)
    } else {
        Err(FxpError::config(format!(
            "perf gates failed:\n  {}",
            violations.join("\n  ")
        )))
    }
}

/// `fxpnet serve`: the micro-batching inference daemon, or (with
/// `--replay`) the trace-replay load bench against a running daemon.
fn serve_cmd(args: &Args) -> Result<()> {
    if args.has("replay") {
        serve_replay(args)
    } else {
        serve_daemon(args)
    }
}

/// Parse a `--w`/`--a` width with a default (unlike [`width`], serving
/// has sensible defaults: the 8/8 cell).
fn width_or(args: &Args, key: &str, default: &str) -> Result<WidthSpec> {
    let v = args.get_or(key, default);
    WidthSpec::parse(&v)
        .ok_or_else(|| FxpError::config(format!("bad --{key} '{v}'")))
}

fn serve_daemon(args: &Args) -> Result<()> {
    let arch = args.get_or("arch", "tiny");
    let cfg = run_cfg(args, all_cores())?;
    let w = width_or(args, "w", "8")?;
    let a = width_or(args, "a", "8")?;
    if w == WidthSpec::Float || a == WidthSpec::Float {
        return Err(FxpError::config(
            "integer serving needs fixed-point --w and --a",
        ));
    }
    // same model construction as `train`/`eval`: --ckpt when given, else
    // a fresh deterministic He init; calibration on the synthetic
    // training stream
    let backend = backend_spec(args)?.build_with_threads(cfg.threads)?;
    let spec = backend.arch(&arch)?;
    let params = base_params(args, &spec, backend.as_ref(), cfg.seed)?;
    let (train, _eval) = datasets(args, &spec)?;
    let a_stats =
        backend.activation_stats(&arch, &params, &train, cfg.calib_batches)?;
    let nq =
        NetQuant::for_cell(w, a, &params.weight_stats(), &a_stats, cfg.method)?;
    let net = FixedPointNet::build(&spec, &params, &nq, QFormat::new(16, 14)?)?;

    let opts = crate::serve::ServeOpts {
        listen: args.get_or("listen", "127.0.0.1:0"),
        port_file: args.get("port-file").map(std::path::PathBuf::from),
        max_batch: args.usize_or("max-batch", 8)?.max(1),
        max_wait: std::time::Duration::from_micros(
            args.u64_or("max-wait-us", 2000)?,
        ),
        max_queue: args.usize_or("max-queue", 64)?,
        threads: cfg.threads,
    };
    log::info!(
        "serving {arch} ({w:?}/{a:?}, {:.0} MMAC/img)",
        net.macs_per_image() as f64 / 1e6
    );
    let shutdown = cluster::install_drain_handler();
    let summary =
        crate::serve::run_server(std::sync::Arc::new(net), &opts, shutdown, None)?;
    println!("{}", summary.to_json());
    Ok(())
}

fn serve_replay(args: &Args) -> Result<()> {
    let addr = cluster_connect(args)?;
    let traces = args
        .get_or("traces", "uniform,bursty")
        .split(',')
        .map(|s| crate::serve::TraceKind::parse(s.trim()))
        .collect::<Result<Vec<_>>>()?;
    let opts = crate::serve::ReplayOpts {
        requests: args.usize_or("requests", 400)?,
        clients: args.usize_or("clients", 0)?,
        seed: args.u64_or("seed", 42)?,
        traces,
        out: args.get("out").map(std::path::PathBuf::from),
        assert_floors: args.has("assert")
            || std::env::var("FXP_BENCH_ASSERT").is_ok(),
    };
    let report = crate::serve::replay::run_suite(&addr, &opts)?;
    println!("{}", report.get("gates")?);
    Ok(())
}

/// `fxpnet eval`: single-cell evaluation of a checkpoint.
fn eval_cmd(args: &Args) -> Result<()> {
    let arch = args.get_or("arch", "paper12");
    let cfg = run_cfg(args, all_cores())?;
    let backend = backend_spec(args)?.build_with_threads(cfg.threads)?;
    let spec = backend.arch(&arch)?;
    let params = load_ckpt(args, &spec)?;
    let (train, eval_set) = datasets(args, &spec)?;
    let w = width(args, "w")?;
    let a = width(args, "a")?;
    let a_stats =
        backend.activation_stats(&arch, &params, &train, cfg.calib_batches)?;
    let nq = NetQuant::for_cell(
        w,
        a,
        &params.weight_stats(),
        &a_stats,
        cfg.method,
    )?;
    let ev = backend.evaluate(&arch, &params, &nq, &eval_set)?;
    println!(
        "{arch} w={} a={}: top-1 {:.2}%  top-5 {:.2}%  loss {:.4}  (n={})",
        w.label(),
        a.label(),
        ev.top1_err * 100.0,
        ev.top5_err * 100.0,
        ev.mean_loss,
        ev.n
    );
    Ok(())
}

/// `fxpnet infer`: pure-integer engine + parity report vs the XLA path.
fn infer(args: &Args) -> Result<()> {
    let arch = args.get_or("arch", "paper12");
    let engine = Engine::cpu(artifacts_dir(args))?;
    let cfg = run_cfg(args, all_cores())?;
    let spec = engine.manifest.arch(&arch)?.clone();
    let params = load_ckpt(args, &spec)?;
    let (train, eval_set) = datasets(args, &spec)?;
    let w = width(args, "w")?;
    let a = width(args, "a")?;
    if w == WidthSpec::Float || a == WidthSpec::Float {
        return Err(FxpError::config(
            "integer inference needs fixed-point --w and --a",
        ));
    }
    let calib = calibrate::activation_stats(
        &engine,
        &arch,
        &params,
        &train,
        cfg.calib_batches,
    )?;
    let nq = NetQuant::for_cell(
        w,
        a,
        &params.weight_stats(),
        &calib.a_stats,
        cfg.method,
    )?;
    let net = FixedPointNet::build(&spec, &params, &nq, QFormat::new(16, 14)?)?;

    // integer path on a slice of the eval set (batched GEMM engine,
    // row-blocks sharded over --threads workers; bit-identical logits
    // for any thread count)
    let threads = cfg.threads;
    let n = args.usize_or("eval-n", 256)?.min(eval_set.len());
    let rows: Vec<usize> = (0..n).collect();
    let images = eval_set.images.gather_rows(&rows)?;
    let labels = eval_set.labels.gather_rows(&rows)?;
    let t = std::time::Instant::now();
    let int_logits = net.forward_batch_threaded(&images, threads)?;
    let dt = t.elapsed().as_secs_f64();
    let top1 = int_logits.topk_rows(1)?;
    let wrong = (0..n)
        .filter(|&i| top1[i][0] != labels.data()[i] as usize)
        .count();
    println!(
        "integer engine: {n} images in {:.2}s ({:.1} img/s, {:.0} MMAC/img, \
         {threads} threads), top-1 error {:.2}%",
        dt,
        n as f64 / dt,
        net.macs_per_image() as f64 / 1e6,
        100.0 * wrong as f64 / n as f64
    );

    // parity vs the XLA simulated-quantization path
    let sub = Dataset { images, labels, h: spec.input[0], w: spec.input[1] };
    let xla_ev = evaluate(&engine, &arch, &params, &nq, &sub)?;
    let full = evaluate_logits(&engine, &arch, &params, &nq, &sub)?;
    let parity = parity_report(&int_logits, &full)?;
    println!("XLA path:      top-1 error {:.2}%", xla_ev.top1_err * 100.0);
    println!("parity:        {parity}");
    Ok(())
}

/// Collect XLA-path logits for a dataset (helper for parity checks).
pub fn evaluate_logits(
    engine: &Engine,
    arch: &str,
    params: &ParamSet,
    nq: &NetQuant,
    data: &Dataset,
) -> Result<crate::tensor::TensorF> {
    use crate::data::loader::sequential_batches;
    use crate::runtime::literal::{to_literal, HostValue};
    let spec = engine.manifest.arch(arch)?;
    let exe = engine.executable(arch, "eval_batch")?;
    let v = nq.vectors();
    let mk = |x: &[f32]| -> Result<xla::Literal> {
        to_literal(&HostValue::F32(crate::tensor::Tensor::from_vec(
            &[x.len()],
            x.to_vec(),
        )?))
    };
    let cfg = [
        mk(&v.w_step)?,
        mk(&v.w_lo)?,
        mk(&v.w_hi)?,
        mk(&v.w_en)?,
        mk(&v.a_step)?,
        mk(&v.a_lo)?,
        mk(&v.a_hi)?,
        mk(&v.a_en)?,
    ];
    let param_lits: Vec<xla::Literal> = params
        .tensors
        .iter()
        .map(|t| to_literal(&HostValue::F32(t.clone())))
        .collect::<Result<_>>()?;
    let mut all = Vec::new();
    let mut total = 0usize;
    for (images, labels, valid) in sequential_batches(data, spec.eval_batch)? {
        let x = to_literal(&HostValue::F32(images))?;
        let y = to_literal(&HostValue::I32(labels))?;
        let mut inputs: Vec<&xla::Literal> = Vec::new();
        inputs.extend(param_lits.iter());
        inputs.push(&x);
        inputs.push(&y);
        inputs.extend(cfg.iter());
        let outs = exe.run_literals(&inputs)?;
        let logits = exe.output_host(&outs, "logits")?.into_f32()?;
        let nc = logits.shape()[1];
        all.extend_from_slice(&logits.data()[..valid * nc]);
        total += valid;
    }
    crate::tensor::Tensor::from_vec(
        &[total, engine.manifest.arch(arch)?.num_classes],
        all,
    )
}

/// `fxpnet mismatch`: per-layer cosine between float and quantized-path
/// gradients (the section 2.2 analysis).
fn mismatch(args: &Args) -> Result<()> {
    let arch = args.get_or("arch", "paper12");
    let engine = Engine::cpu(artifacts_dir(args))?;
    let cfg = run_cfg(args, 1)?;
    let spec = engine.manifest.arch(&arch)?.clone();
    let params = load_ckpt(args, &spec)?;
    let (train, _) = datasets(args, &spec)?;
    let bits = args.usize_or("bits", 8)? as u8;
    let calib = calibrate::activation_stats(
        &engine,
        &arch,
        &params,
        &train,
        cfg.calib_batches,
    )?;
    let report = crate::coordinator::mismatch::gradient_mismatch(
        &engine,
        &arch,
        &params,
        &calib.a_stats,
        &train,
        bits,
        cfg.method,
    )?;
    println!(
        "gradient mismatch, arch {arch}, {}w/{}a (cos(float grad, quantized grad)):",
        bits, bits
    );
    for (l, c) in report.iter().enumerate() {
        let bar = "#".repeat((c.max(0.0) * 40.0) as usize);
        println!("  layer {l:>2}  cos {c:+.4}  {bar}");
    }
    let low = report[..spec.num_layers / 3].iter().sum::<f64>()
        / (spec.num_layers / 3) as f64;
    let high = report[spec.num_layers - spec.num_layers / 3..]
        .iter()
        .sum::<f64>()
        / (spec.num_layers / 3) as f64;
    println!(
        "bottom-third mean {low:+.4} vs top-third mean {high:+.4} -- mismatch \
         accumulates toward the bottom (section 2.2)"
    );
    Ok(())
}
