//! Hand-rolled CLI (clap is not in the offline crate cache).
//!
//! Grammar: `fxpnet <command> [positional]... [--flag value | --switch]...`
//!
//! Positionals carry subcommands and file lists (`grid merge <out>
//! <in>...`).  Because `--flag value` greedily consumes the next bare
//! token, positionals must come before flag/value pairs; commands that
//! take no positionals reject strays via [`Args::no_positionals`].

pub mod commands;

use std::collections::BTreeMap;

use crate::coordinator::shard;
use crate::error::{FxpError, Result};

/// Flags that never take a value.  The parser needs this registry
/// because `--flag value` is greedy: without it, a switch followed by a
/// bare token (`grid merge --render out.json in.json`) would silently
/// swallow the token as the switch's "value" -- and for `merge` that
/// misparse would shift the output path onto a shard input and
/// overwrite it.  Add every new boolean flag here.
const KNOWN_SWITCHES: &[&str] = &[
    "assert",
    "check",
    "gate",
    "no-early-abort",
    "prune",
    "render",
    "replay",
    "resume",
    "shard-cache",
    "synthetic",
];

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    positionals: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut it = argv.into_iter().peekable();
        let command = it
            .next()
            .ok_or_else(|| FxpError::config("missing command; try `fxpnet help`"))?;
        let mut flags = BTreeMap::new();
        let mut switches = Vec::new();
        let mut positionals = Vec::new();
        while let Some(a) = it.next() {
            let Some(name) = a.strip_prefix("--") else {
                positionals.push(a);
                continue;
            };
            // --key=value or --switch or --key value
            if let Some((k, v)) = name.split_once('=') {
                flags.insert(k.to_string(), v.to_string());
            } else if KNOWN_SWITCHES.contains(&name) {
                switches.push(name.to_string());
            } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                flags.insert(name.to_string(), it.next().unwrap());
            } else {
                switches.push(name.to_string());
            }
        }
        Ok(Args { command, flags, switches, positionals })
    }

    /// Positional arguments, in order (e.g. `merge out.json in0.json`).
    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }

    /// Error if any positional argument was given (commands without
    /// positional grammar keep the strict old behavior).
    pub fn no_positionals(&self) -> Result<()> {
        match self.positionals.first() {
            None => Ok(()),
            Some(p) => {
                Err(FxpError::config(format!("unexpected argument '{p}'")))
            }
        }
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| FxpError::config(format!("--{key}: bad integer '{v}'"))),
        }
    }

    pub fn f32_or(&self, key: &str, default: f32) -> Result<f32> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| FxpError::config(format!("--{key}: bad float '{v}'"))),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| FxpError::config(format!("--{key}: bad integer '{v}'"))),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn require(&self, key: &str) -> Result<&str> {
        self.get(key)
            .ok_or_else(|| FxpError::config(format!("missing required flag --{key}")))
    }
}

pub const USAGE: &str = "\
fxpnet -- fixed-point DCN training (Lin & Talathi 2016 reproduction)

USAGE: fxpnet <command> [flags]

COMMANDS
  pretrain   train the float baseline network
             --arch A --steps N --out ckpt [--from ckpt] [--lr F] [--train-n N]
  train      one fine-tuning run at a single (w, a) cell, with the
             convergence verdict on stdout -- the CI gate for the native
             engine
             [--arch A] [--ckpt F]  start from a checkpoint (default:
                                    fresh He init from --seed)
             [--w B] [--a B]        cell widths (default 8/8)
             [--steps N] [--out F]  save the tuned net
             [--threads N]          GEMM/gradient workers inside the
                                    training step (default: all cores;
                                    loss histories are bit-identical
                                    for any count)
             [--gate]               exit non-zero unless the final loss
                                    improved on the initial loss
             [--stability-report F] write per-step telemetry (loss,
                                    per-layer gradient/update norms,
                                    update-to-quantization-step ratio,
                                    saturation counts) as JSON
  grid       run one experiment grid (a paper table), in parallel
             --arch A --regime {none|vanilla|prop1|prop2|prop3} --ckpt F
             (--ckpt is optional with --backend native: a fresh He init
             from --seed is used, e.g. for CI sweeps)
             [--out DIR] [--steps N] [--phase-steps N] [--train-n N]
             [--eval-n N] [--calib {minmax|sqnr}] [--topk K]
             [--workers N]   worker threads, one cell each (default: all
                             cores; results are bit-identical for any
                             worker count)
             [--threads N]   GEMM/gradient workers *inside* each cell's
                             training/eval (default 1: cells already run
                             in parallel across --workers; results are
                             bit-identical for any count)
             [--shard I/N]   run only cells with flat_index % N == I
             [--resume]      skip cells already in the cell cache
             [--cache FILE]  cell cache path (default when sharding or
                             resuming: OUT/cache_table<T>_<ARCH>.json);
                             lock-protected, so concurrent processes can
                             share one cache file; "n/a" outcomes are
                             cached too
             [--shard-cache] with --shard I/N, write a per-shard
                             FILE-derived cache.shard-I-of-N.json for
                             `grid merge` (shards need not share a
                             filesystem)
             [--lock-wait S] seconds to wait for the cache lock (def 10)
             [--synthetic]   engine-free deterministic cells (no --ckpt
                             or artifacts needed; exercises the sweep /
                             shard / cache plumbing, e.g. in CI)
             [--stability-report F]  write the per-cell stability report
                             (ok/na/aborted + abort reason/step) as JSON
  NOTE: fine-tuning cells whose training is provably doomed (NaN loss,
  sustained loss blow-up, saturation-rate or update-collapse predicates)
  are ended early by default and render as `div@<step>`; pass
  --no-early-abort to always burn the full step budget.  Completed
  cells' results are bit-identical either way.
  grid plan  print the sweep manifest + per-shard cell lists, so external
             schedulers (CI matrix, cluster) can launch one job per shard
             --regime R [--arch A] [--seed S] [--shards N]
             [--manifest FILE]  also write the manifest JSON (the same
                                file `grid merge --manifest` verifies)
  grid merge union per-shard cell caches into one (no re-running):
             fxpnet grid merge <out.json> <in.json>... [flags]
             Strict: version/sweep header mismatches and conflicting
             results for the same cell are hard errors; *.tmp/*.lock
             litter among the inputs is skipped.
             [--manifest F]  verify the inputs partition F's sweep
             [--render]      print the merged table (exact save_grid
                             bytes) on stdout
             [--topk K]      metric for --render (default 1)
             [--check]       exit 0 iff the sweep is complete, 2 if
                             cells are missing (listed on stderr)
             [--prune]       after a complete merge, delete the merged
                             per-shard cache.shard-I-of-N.json inputs
                             (refused while any cell is missing)
             [--stability-report F]  write the merged sweep's per-cell
                             stability report JSON
  cluster    elastic multi-process/multi-machine sweeps over TCP: one
             coordinator owns the sweep, workers pull cells and may
             come, go, or die at any time.  Same cache/table bytes as a
             single-process `grid` run.  (`grid plan` + `--shard` stays
             as the static-scheduler escape hatch.)
  cluster coordinator
             serve one regime's grid to workers, write cache + table
             --regime R [--arch A] [--seed S] [--synthetic]
             [--listen H:P]     bind address (default 127.0.0.1:0)
             [--port-file F]    write the bound host:port here (the
                                rendezvous for port 0)
             [--cache FILE]     cell cache, same schema/path default as
                                `grid`; resume is always on (crash
                                recovery)
             [--out DIR]        table/report JSON on completion
             [--summary F]      run-accounting JSON (re-dispatches,
                                duplicates, worker deaths...)
             [--retry-cap N]    max attempts per cell before the run
                                fails hard (default 5)
             [--backoff-ms MS]  re-dispatch backoff base, doubling per
                                attempt (default 100)
             [--heartbeat-ms MS] worker heartbeat interval (default 1000)
             [--deadline-ms MS] silence declaring a worker dead
                                (default 5000)
             [--lock-wait S]    cache lock wait (default 10)
             exit 0 = sweep complete; 2 = drained (SIGTERM/ctrl-C)
             before completion
  cluster worker
             compute cells for a coordinator until drained
             --connect H:P (or --port-file F to poll a coordinator's
             port file); sweep flags (--regime/--arch/--seed/--steps/
             --synthetic/...) MUST match the coordinator's -- a sweep
             fingerprint is checked at handshake
             [--name S]         worker identity (default host-pid)
             [--shard I/N]      only compute this static slice
             [--reconnect N]    reconnect attempts (default 8)
             [--connect-timeout-ms MS]
                                TCP connect budget per attempt (default
                                10000); replies from a connected-but-
                                hung coordinator are additionally
                                bounded by its advertised deadline-ms,
                                so no coordinator failure mode can wedge
                                a worker past its backoff budget
             [--inject drop=P,delay=MS,kill-after=N]
                                deterministic fault injection (chaos
                                tests): drop each send with prob P,
                                delay sends MS, die after N cells
  serve      micro-batching inference daemon for the pure-integer
             engine: concurrent TCP clients' requests coalesce into one
             GEMM batch under a latency budget; replies (logits, argmax,
             server-side timing) are bit-identical to a batch-of-1 run
             whatever batch a request lands in
             [--arch A] [--ckpt F] [--w B] [--a B]
                                model cell (defaults: tiny, 8/8, fresh
                                He init from --seed like `train`)
             [--listen H:P]     bind address (default 127.0.0.1:0)
             [--port-file F]    write the bound host:port here (the
                                rendezvous for port 0)
             [--max-batch N]    largest GEMM batch one flush may form
                                (default 8)
             [--max-wait-us US] latency budget: longest a queued request
                                waits before a partial batch flushes
                                (default 2000)
             [--max-queue N]    admission queue depth bound; overflow is
                                refused with an explicit `busy` reply
                                (0 = unbounded, default 64)
             SIGINT/SIGTERM drain gracefully: queued requests still
             reply, new ones get an error, then exit 0
  serve --replay
             trace-replay load bench against a running daemon; writes
             BENCH_serve.json (p50/p95/p99, throughput, batch-size mix)
             --connect H:P (or --port-file F to poll the daemon's)
             [--traces L]       comma list of uniform|bursty|diurnal|
                                adversarial (default uniform,bursty);
                                offered rates derive from a measured
                                serial baseline, so gates are machine-
                                independent ratios
             [--requests N]     requests per trace (default 400)
             [--clients N]      client connections (default 2*max_batch)
             [--seed S]         arrival jitter + image pool seed
             [--out F]          report path (default BENCH_serve.json)
             [--assert]         enforce the `serve` ratio gates from
                                BENCH_baseline.json (FXP_BENCH_ASSERT=1
                                does the same; violations exit non-zero)
  report     grid-wide stability analytics over finished sweeps:
             fxpnet report <cache.json|stability.json>... [flags]
             Inputs auto-detect per file (v4 cell cache vs v2 stability
             report written by --stability-report); unversioned or
             version-mismatched reports are refused.  The table and
             --json bytes are a pure function of the union of cells, so
             any --threads / --shard / grid-vs-cluster provenance
             covering the same sweeps reports byte-identically.
             [--json F]      write the analytics JSON
             [--suggest-thresholds F]
                             fit per-regime abort thresholds separating
                             converged from doomed cells (deterministic
                             closed-form, no RNG) and write an
                             abort-policy overlay for --abort-policy; a
                             policy learned from a sweep never aborts a
                             cell that converged in that sweep
  perf       the consolidated perf-trajectory gate:
             fxpnet perf <BENCH.json>... [--baseline F]
             Diff each measured bench report (BENCH_engine.json,
             BENCH_train.json, BENCH_serve.json) against the committed
             ratio floors (--baseline, default BENCH_baseline.json);
             every comparison lands in one table and any violated key
             exits non-zero.  Absent baseline sections or unmeasured
             keys (e.g. the threaded gate on one core) are skipped with
             a note
  eval       evaluate a checkpoint at one grid cell
             --arch A --ckpt F --w {4|8|16|float} --a {4|8|16|float}
  infer      pure-integer inference + parity vs the XLA path
             --arch A --ckpt F --w B --a B [--eval-n N] [--threads N]
  mismatch   per-layer gradient mismatch (section 2.2 analysis)
             --arch A --ckpt F [--bits B]
  table1     print the Proposal 3 phase schedule  [--layers N]
  help       this text

COMMON FLAGS
  --backend {native|xla}
                    training/eval engine: 'native' is the pure-Rust
                    backprop + stochastic-rounding SGD engine (no
                    artifacts needed); 'xla' is the AOT/PJRT path.
                    Default: xla when ARTIFACTS/manifest.json exists,
                    native otherwise
  --threads N       one spelling everywhere (infer/train/pretrain/eval/
                    grid): GEMM row-block + gradient workers inside one
                    forward/step.  Accumulation order is fixed and the
                    stochastic-rounding streams are pre-split, so every
                    result -- logits, loss histories, grid tables -- is
                    bit-identical for any N.  Default: all cores, except
                    under `grid` where it is 1 (cells already run in
                    parallel across --workers)
  --artifacts DIR   artifact directory (default: ./artifacts or
                    $FXPNET_ARTIFACTS)
  --abort-policy F  abort-threshold overlay JSON (e.g. written by
                    `fxpnet report --suggest-thresholds`): per-regime
                    early-abort thresholds for train/grid/cluster runs.
                    Ignored under --no-early-abort; cluster roles fold
                    the resolved thresholds into the sweep fingerprint,
                    so coordinator and workers must agree on it
";

/// Resolve the artifacts directory.
pub fn artifacts_dir(args: &Args) -> String {
    args.get("artifacts")
        .map(|s| s.to_string())
        .or_else(|| std::env::var("FXPNET_ARTIFACTS").ok())
        .unwrap_or_else(|| "artifacts".to_string())
}

/// Parse a `--shard I/N` value.  Shared by `grid --shard` and
/// `cluster worker --shard`; rejection happens at parse time through
/// [`shard::validate_shard`], the same rule the sweep itself enforces.
pub fn parse_shard(s: &str) -> Result<(usize, usize)> {
    let bad = |why: &str| {
        FxpError::config(format!("bad --shard '{s}': {why}"))
    };
    let (i, n) = s
        .split_once('/')
        .ok_or_else(|| bad("expected I/N (e.g. 0/4)"))?;
    let index: usize = i
        .trim()
        .parse()
        .map_err(|_| bad(&format!("shard index '{}' is not an integer", i.trim())))?;
    let count: usize = n
        .trim()
        .parse()
        .map_err(|_| bad(&format!("shard count '{}' is not an integer", n.trim())))?;
    shard::validate_shard(index, count)
        .map_err(|e| FxpError::config(format!("--shard '{s}': {e}")))?;
    Ok((index, count))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn parse_flags_and_switches() {
        let a = parse(&["grid", "--arch", "tiny", "--steps=12", "--verbose"]);
        assert_eq!(a.command, "grid");
        assert_eq!(a.get("arch"), Some("tiny"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 12);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
        assert_eq!(a.get_or("missing", "d"), "d");
    }

    #[test]
    fn parse_errors() {
        assert!(Args::parse(Vec::<String>::new()).is_err());
        let a = parse(&["cmd", "--n", "abc"]);
        assert!(a.usize_or("n", 1).is_err());
        assert!(a.require("nope").is_err());
    }

    #[test]
    fn positionals_are_collected_and_rejectable() {
        let a = parse(&["grid", "merge", "out.json", "a.json", "b.json", "--check"]);
        assert_eq!(a.command, "grid");
        assert_eq!(a.positionals(), ["merge", "out.json", "a.json", "b.json"]);
        assert!(a.has("check"));
        assert!(a.no_positionals().is_err());

        // commands without positional grammar keep the strict behavior
        let a = parse(&["eval", "stray"]);
        let err = a.no_positionals().unwrap_err();
        assert!(err.to_string().contains("stray"));
        assert!(parse(&["grid", "--workers", "2"]).no_positionals().is_ok());
    }

    #[test]
    fn known_switches_never_swallow_positionals() {
        // `--render out.json ...`: render must stay a switch, out.json a
        // positional -- a misparse here would shift merge's output path
        // onto a shard input and overwrite it
        let a = parse(&["grid", "merge", "--render", "o.json", "a.json", "--check"]);
        assert!(a.has("render"));
        assert!(a.has("check"));
        assert_eq!(a.get("render"), None);
        assert_eq!(a.positionals(), ["merge", "o.json", "a.json"]);
        // value-taking flags still consume the next bare token
        let a = parse(&["grid", "--cache", "c.json", "--resume", "--workers", "2"]);
        assert_eq!(a.get("cache"), Some("c.json"));
        assert!(a.has("resume"));
        assert_eq!(a.usize_or("workers", 0).unwrap(), 2);
    }

    #[test]
    fn trailing_switch() {
        let a = parse(&["cmd", "--x", "1", "--flag"]);
        assert!(a.has("flag"));
        assert_eq!(a.get("x"), Some("1"));
    }

    #[test]
    fn shard_parsing() {
        assert_eq!(parse_shard("0/4").unwrap(), (0, 4));
        assert_eq!(parse_shard("3/4").unwrap(), (3, 4));
        assert!(parse_shard("4/4").is_err());
        assert!(parse_shard("0/0").is_err());
        assert!(parse_shard("1").is_err());
        assert!(parse_shard("a/b").is_err());
        assert!(parse_shard("-1/2").is_err());
        // rejection is at parse time with a message naming the rule,
        // via the same validate_shard the sweep itself enforces
        let e = parse_shard("4/4").unwrap_err().to_string();
        assert!(e.contains("index"), "unhelpful message: {e}");
        assert!(e.contains("4/4"), "message must echo the input: {e}");
        let e = parse_shard("0/0").unwrap_err().to_string();
        assert!(e.contains("count must be > 0"), "unhelpful message: {e}");
        let e = parse_shard("x/2").unwrap_err().to_string();
        assert!(e.contains("not an integer"), "unhelpful message: {e}");
    }
}
