"""AOT pipeline: lower every (arch x artifact-kind) to HLO **text** and
write the JSON manifest the Rust runtime consumes.

HLO text -- not ``.serialize()`` -- is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` 0.1.6 crate binds) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/README.md.

Run once at build time (``make artifacts``); Python never appears on the
Rust request path.

Usage:
    python -m compile.aot [--out-dir ../artifacts] [--arch NAME ...]
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_tag(sds) -> str:
    return {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32"}[sds.dtype]


def _io_entry(name, sds):
    return {"name": name, "shape": list(sds.shape), "dtype": _dtype_tag(sds)}


def input_names(arch: str, kind: str):
    """Ordered input names matching model.example_args (the Rust runtime
    feeds literals in exactly this order)."""
    pnames = [n for n, _ in model.param_shapes(arch)]
    cfg_w = ["w_step", "w_lo", "w_hi", "w_en"]
    cfg_a = ["a_step", "a_lo", "a_hi", "a_en"]
    if kind == "train_step":
        return (pnames + [f"m.{n}" for n in pnames] + ["x", "y"]
                + cfg_w + cfg_a + ["upd", "lr", "mu"])
    if kind == "eval_batch":
        return pnames + ["x", "y"] + cfg_w + cfg_a
    if kind == "stats_batch":
        return pnames + ["x"] + cfg_w + cfg_a
    if kind == "grads":
        return pnames + ["x", "y"] + cfg_w + cfg_a
    raise ValueError(kind)


def output_names(arch: str, kind: str):
    pnames = [n for n, _ in model.param_shapes(arch)]
    if kind == "train_step":
        return pnames + [f"m.{n}" for n in pnames] + ["loss"]
    if kind == "eval_batch":
        return ["logits", "loss_sum"]
    if kind == "stats_batch":
        return ["absmax", "meanabs", "meansq"]
    if kind == "grads":
        return ["loss"] + [f"g.{n}" for n in pnames]
    raise ValueError(kind)


def build_arch(arch: str, out_dir: str, kinds=model.ARTIFACT_KINDS):
    """Lower all artifact kinds for ``arch``; returns its manifest dict."""
    spec = model.ARCHS[arch]
    entry = {
        "input": list(spec["input"]),
        "num_classes": model.NUM_CLASSES,
        "num_layers": model.num_layers(arch),
        "train_batch": spec["train_batch"],
        "eval_batch": spec["eval_batch"],
        "layers": [
            {"kind": l[0], **({"out": l[1]} if len(l) > 1 else {})}
            for l in spec["layers"]
        ],
        "params": [
            {"name": n, "shape": list(s)} for n, s in model.param_shapes(arch)
        ],
        "artifacts": {},
    }
    for kind in kinds:
        fn = model.make_fn(arch, kind)
        args = model.example_args(arch, kind)
        print(f"[aot] lowering {arch}/{kind} ({len(args)} inputs) ...",
              flush=True)
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{arch}_{kind}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        names = input_names(arch, kind)
        assert len(names) == len(args), (arch, kind, len(names), len(args))
        # output shapes from the lowered signature
        out_avals = lowered.out_info
        flat = jax.tree_util.tree_leaves(out_avals)
        onames = output_names(arch, kind)
        assert len(onames) == len(flat), (arch, kind, len(onames), len(flat))
        entry["artifacts"][kind] = {
            "file": fname,
            "inputs": [_io_entry(n, a) for n, a in zip(names, args)],
            "outputs": [_io_entry(n, a) for n, a in zip(onames, flat)],
        }
        print(f"[aot]   wrote {fname}: {len(text)} chars", flush=True)
    return entry


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--arch", action="append", default=None,
                    help="restrict to these architectures (default: all)")
    ap.add_argument("--kind", action="append", default=None,
                    help="restrict to these artifact kinds (default: all)")
    ap.add_argument("--backend", default="pallas", choices=["pallas", "jnp"],
                    help="kernel backend: the L1 Pallas kernels (default) or "
                         "their pure-jnp twins (perf ablation; write to a "
                         "separate --out-dir)")
    args = ap.parse_args()
    model.set_backend(args.backend)
    archs = args.arch or list(model.ARCHS)
    kinds = tuple(args.kind) if args.kind else model.ARTIFACT_KINDS
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"version": 1, "archs": {}}
    for arch in archs:
        manifest["archs"][arch] = build_arch(arch, args.out_dir, kinds)
    mpath = os.path.join(args.out_dir, "manifest.json")
    # merge with an existing manifest so partial rebuilds keep other archs
    if os.path.exists(mpath) and (args.arch or args.kind):
        with open(mpath) as f:
            old = json.load(f)
        merged = old.get("archs", {})
        for k, v in manifest["archs"].items():
            if args.kind and k in merged:
                merged[k]["artifacts"].update(v["artifacts"])
            else:
                merged[k] = v
        manifest["archs"] = merged
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {mpath}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
