"""L2: quantization-aware CNN forward/backward in JAX (build-time only).

Implements the paper's simulated fixed-point network:

* master weights are float; the forward pass sees ``q(w)`` (per-layer
  runtime format) -- the paper: "weights can follow the desired fixed
  point format without special treatment";
* each layer's **pre-activation** is quantized (Figure 1 step 3 -- for FC
  layers via the fused L1 ``qmatmul`` kernel, for conv layers via XLA's
  convolution + the L1 elementwise quantizer), then ReLU is applied, so
  the *effective* activation function is the staircase of Figure 2(b);
* the backward pass uses the straight-through estimator: gradients of the
  smooth float graph (Figure 2(a)).  The disagreement between the two is
  exactly the paper's "gradient mismatch", physically present in every
  fine-tuning run this library performs.

Everything the experiments vary is a **runtime input** (per-layer
quantization step/clip/enable vectors, per-layer update masks, learning
rate, momentum), so each architecture compiles to just four executables
(train_step / eval_batch / stats_batch / grads); the Rust coordinator
drives the whole experiment grid -- including the Table 1 phase schedule
of Proposal 3 -- as pure data.

Conventions
-----------
* images: NHWC f32; labels: int32 class ids.
* ``params``: flat list [w0, b0, w1, b1, ...] in layer order; conv w is
  HWIO, fc w is (in, out).
* quant config vectors: shape (L,) f32 -- ``a_step/a_lo/a_hi/a_en`` for
  pre-activations, ``w_step/w_lo/w_hi/w_en`` for weights, ``upd`` for the
  per-layer update mask; scalars ``lr``, ``mu`` are shape (1,).
* biases are kept in the wide-accumulator precision (not quantized),
  matching the hardware model of Figure 1.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import quantize as qz
from .kernels import qmatmul as qm

# Kernel backend: "pallas" (default; the L1 kernels, interpret-lowered)
# or "jnp" (pure-jnp twins) -- the EXPERIMENTS.md section Perf ablation.
_BACKEND = "pallas"


def set_backend(name: str) -> None:
    global _BACKEND
    if name not in ("pallas", "jnp"):
        raise ValueError(f"unknown backend {name!r}")
    _BACKEND = name


def _quantize_ste(*args):
    fn = qz.quantize_ste if _BACKEND == "pallas" else qz.quantize_ste_jnp
    return fn(*args)


def _qmatmul_ste(*args):
    fn = qm.qmatmul_ste if _BACKEND == "pallas" else qm.qmatmul_ste_jnp
    return fn(*args)

# ---------------------------------------------------------------------------
# architecture registry
# ---------------------------------------------------------------------------

# Layer kinds: ("conv", out_ch) 3x3 SAME stride 1; "pool" 2x2 max;
# ("fc", out). The first fc flattens. L counts weighted layers only.
ARCHS: Dict[str, Dict[str, Any]] = {
    # Deep net standing in for the paper's 12-conv + 5-fc ImageNet DCN:
    # 8 conv + 3 fc = 11 weighted layers on 32x32x3 inputs (DESIGN.md sec.2).
    "paper12": {
        "input": (32, 32, 3),
        "layers": [
            ("conv", 32), ("conv", 32), ("pool",),
            ("conv", 48), ("conv", 48), ("pool",),
            ("conv", 64), ("conv", 64), ("pool",),
            ("conv", 96), ("conv", 96),
            ("fc", 256), ("fc", 128), ("fc", 10),
        ],
        "train_batch": 64,
        "eval_batch": 128,
    },
    # Shallow contrast net (the paper: shallow nets fine-tune fine even at
    # small bit-widths -- cf. their CIFAR-10 remark in section 3).
    "shallow": {
        "input": (32, 32, 3),
        "layers": [
            ("conv", 32), ("pool",),
            ("conv", 64), ("pool",),
            ("fc", 128), ("fc", 10),
        ],
        "train_batch": 64,
        "eval_batch": 128,
    },
    # Test/bench architecture: small and fast.
    "tiny": {
        "input": (16, 16, 3),
        "layers": [
            ("conv", 8), ("pool",),
            ("conv", 16), ("pool",),
            ("fc", 10),
        ],
        "train_batch": 16,
        "eval_batch": 32,
    },
}

NUM_CLASSES = 10


def weighted_layers(arch: str) -> List[Tuple[str, int]]:
    """[(kind, out_dim)] for layers that carry parameters, in order."""
    return [l for l in ARCHS[arch]["layers"] if l[0] != "pool"]


def num_layers(arch: str) -> int:
    return len(weighted_layers(arch))


def param_shapes(arch: str) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered [(name, shape)] of the flat parameter list."""
    spec = ARCHS[arch]
    h, w, c = spec["input"]
    shapes: List[Tuple[str, Tuple[int, ...]]] = []
    li = 0
    flat_dim = None
    for layer in spec["layers"]:
        kind = layer[0]
        if kind == "conv":
            out = layer[1]
            shapes.append((f"l{li}.w", (3, 3, c, out)))
            shapes.append((f"l{li}.b", (out,)))
            c = out
            li += 1
        elif kind == "pool":
            h //= 2
            w //= 2
        elif kind == "fc":
            out = layer[1]
            if flat_dim is None:
                flat_dim = h * w * c
                in_dim = flat_dim
            else:
                in_dim = prev_out
            shapes.append((f"l{li}.w", (in_dim, out)))
            shapes.append((f"l{li}.b", (out,)))
            prev_out = out
            li += 1
        else:
            raise ValueError(kind)
    return shapes


def init_params(arch: str, seed: int = 0) -> List[np.ndarray]:
    """He-normal initialisation (numpy; used by pytest -- the Rust side has
    its own initialiser with identical semantics in tensor/init.rs)."""
    rng = np.random.RandomState(seed)
    out = []
    for name, shape in param_shapes(arch):
        if name.endswith(".b"):
            out.append(np.zeros(shape, np.float32))
        else:
            fan_in = int(np.prod(shape[:-1]))
            std = np.sqrt(2.0 / fan_in)
            out.append((rng.randn(*shape) * std).astype(np.float32))
    return out


# ---------------------------------------------------------------------------
# forward pass
# ---------------------------------------------------------------------------


def _slice1(v, i: int):
    """(1,)-shaped runtime scalar from a (L,) config vector, static index."""
    return jax.lax.dynamic_slice_in_dim(v, i, 1)


def _max_pool(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def forward(
    arch: str,
    params: List[jax.Array],
    x: jax.Array,
    wq,  # (w_step, w_lo, w_hi, w_en)   each (L,)
    aq,  # (a_step, a_lo, a_hi, a_en)   each (L,)
    collect_stats: bool = False,
):
    """Quantized forward pass.

    Returns ``logits`` or, when ``collect_stats``, ``(logits, stats)``
    where stats is a dict of three (L,) vectors over **pre-activations**
    (absmax, mean-abs, mean-square) feeding the Rust-side calibration.
    """
    spec = ARCHS[arch]
    w_step, w_lo, w_hi, w_en = wq
    a_step, a_lo, a_hi, a_en = aq
    li = 0
    pi = 0
    absmax, meanabs, meansq = [], [], []
    h = x
    nw = num_layers(arch)
    for layer in spec["layers"]:
        kind = layer[0]
        if kind == "pool":
            h = _max_pool(h)
            continue
        w, b = params[pi], params[pi + 1]
        pi += 2
        w_q = _quantize_ste(w, _slice1(w_step, li), _slice1(w_lo, li),
                            _slice1(w_hi, li), _slice1(w_en, li))
        if kind == "conv":
            z_f = jax.lax.conv_general_dilated(
                h, w_q, (1, 1), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            ) + b[None, None, None, :]
            # Figure 1 step 3 on the pre-activation (STE backward).
            z = _quantize_ste(z_f, _slice1(a_step, li), _slice1(a_lo, li),
                              _slice1(a_hi, li), _slice1(a_en, li))
        else:  # fc
            if h.ndim == 4:
                h = h.reshape(h.shape[0], -1)
            z = _qmatmul_ste(h, w_q, b, _slice1(a_step, li),
                             _slice1(a_lo, li), _slice1(a_hi, li),
                             _slice1(a_en, li))
            z_f = z  # stats want the accumulator value; STE fwd ~ quantized,
            # but absmax of the quantized value differs from float by <= step,
            # irrelevant for range calibration.
        if collect_stats:
            absmax.append(jnp.max(jnp.abs(z_f)))
            meanabs.append(jnp.mean(jnp.abs(z_f)))
            meansq.append(jnp.mean(z_f * z_f))
        # hidden layers: ReLU; final layer: logits pass through.
        if li < nw - 1:
            h = jnp.maximum(z, 0.0)
        else:
            h = z
        li += 1
    logits = h
    if collect_stats:
        stats = {
            "absmax": jnp.stack(absmax),
            "meanabs": jnp.stack(meanabs),
            "meansq": jnp.stack(meansq),
        }
        return logits, stats
    return logits


def loss_fn(arch, params, x, y, wq, aq):
    logits = forward(arch, params, x, wq, aq)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# the four AOT entry points
# ---------------------------------------------------------------------------


def make_train_step(arch: str):
    """(params..., momenta..., x, y, wq(4), aq(4), upd, lr, mu)
       -> (params'..., momenta'..., loss)

    SGD with momentum, masked per layer:
        v' = upd_l * (mu * v + g) + (1 - upd_l) * v
        p' = p - lr * upd_l * v'
    ``upd`` implements Proposal 2 (top layers only) and each phase of
    Proposal 3 (exactly one layer) without recompilation.
    """
    npar = 2 * num_layers(arch)

    def train_step(*args):
        params = list(args[:npar])
        momenta = list(args[npar:2 * npar])
        x, y = args[2 * npar], args[2 * npar + 1]
        wq = args[2 * npar + 2:2 * npar + 6]
        aq = args[2 * npar + 6:2 * npar + 10]
        upd = args[2 * npar + 10]
        lr = args[2 * npar + 11]
        mu = args[2 * npar + 12]

        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(arch, p, x, y, wq, aq)
        )(params)

        new_p, new_v = [], []
        for i, (p, v, g) in enumerate(zip(params, momenta, grads)):
            u = _slice1(upd, i // 2)[0]
            v2 = u * (mu[0] * v + g) + (1.0 - u) * v
            p2 = p - lr[0] * u * v2
            new_p.append(p2)
            new_v.append(v2)
        return tuple(new_p) + tuple(new_v) + (loss,)

    return train_step


def make_eval_batch(arch: str):
    """(params..., x, y, wq(4), aq(4)) -> (logits, loss_sum)"""
    npar = 2 * num_layers(arch)

    def eval_batch(*args):
        params = list(args[:npar])
        x, y = args[npar], args[npar + 1]
        wq = args[npar + 2:npar + 6]
        aq = args[npar + 6:npar + 10]
        logits = forward(arch, params, x, wq, aq)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
        return (logits, jnp.sum(nll))

    return eval_batch


def make_stats_batch(arch: str):
    """(params..., x, wq(4), aq(4)) -> (absmax, meanabs, meansq) each (L,).

    Run with quantization disabled (en = 0) on the pretrained float net to
    calibrate activation formats; wq/aq stay inputs so calibration can also
    be re-run mid-regime (e.g. after Proposal 3 phases) if desired.
    """
    npar = 2 * num_layers(arch)

    def stats_batch(*args):
        params = list(args[:npar])
        x = args[npar]
        wq = args[npar + 1:npar + 5]
        aq = args[npar + 5:npar + 9]
        _, stats = forward(arch, params, x, wq, aq, collect_stats=True)
        return (stats["absmax"], stats["meanabs"], stats["meansq"])

    return stats_batch


def make_grads(arch: str):
    """(params..., x, y, wq(4), aq(4)) -> (loss, grads...)

    Gradients of the quantized(-STE) graph; the gradient-mismatch analysis
    (DESIGN.md experiment index, section 2.2 claim) compares these against
    the same executable run with all enables = 0 (pure float path).
    """
    npar = 2 * num_layers(arch)

    def grads_fn(*args):
        params = list(args[:npar])
        x, y = args[npar], args[npar + 1]
        wq = args[npar + 2:npar + 6]
        aq = args[npar + 6:npar + 10]
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(arch, p, x, y, wq, aq)
        )(params)
        return (loss,) + tuple(grads)

    return grads_fn


# ---------------------------------------------------------------------------
# example-argument builders (shapes for jax.jit(...).lower)
# ---------------------------------------------------------------------------


def _f32(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def _i32(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.int32)


def example_args(arch: str, kind: str):
    """ShapeDtypeStructs for lowering artifact ``kind`` of ``arch``."""
    spec = ARCHS[arch]
    L = num_layers(arch)
    pshapes = [_f32(s) for _, s in param_shapes(arch)]
    cfgL = [_f32((L,))] * 4
    upd = _f32((L,))
    s1 = _f32((1,))
    if kind == "train_step":
        b = spec["train_batch"]
        x = _f32((b,) + tuple(spec["input"]))
        y = _i32((b,))
        return (*pshapes, *pshapes, x, y, *cfgL, *cfgL, upd, s1, s1)
    if kind == "eval_batch":
        b = spec["eval_batch"]
        x = _f32((b,) + tuple(spec["input"]))
        y = _i32((b,))
        return (*pshapes, x, y, *cfgL, *cfgL)
    if kind == "stats_batch":
        b = spec["eval_batch"]
        x = _f32((b,) + tuple(spec["input"]))
        return (*pshapes, x, *cfgL, *cfgL)
    if kind == "grads":
        b = spec["train_batch"]
        x = _f32((b,) + tuple(spec["input"]))
        y = _i32((b,))
        return (*pshapes, x, y, *cfgL, *cfgL)
    raise ValueError(kind)


ARTIFACT_KINDS = ("train_step", "eval_batch", "stats_batch", "grads")


def make_fn(arch: str, kind: str):
    return {
        "train_step": make_train_step,
        "eval_batch": make_eval_batch,
        "stats_batch": make_stats_batch,
        "grads": make_grads,
    }[kind](arch)
