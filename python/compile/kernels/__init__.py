"""L1 Pallas kernels for fxpnet.

The compute hot-spot of the paper is the fixed-point quantizer: every
weight tensor and every activation tensor in the network passes through
it (Figure 1, step 3).  Two kernels:

* :mod:`quantize`  -- elementwise fixed-point quantizer with runtime
  step/clip parameters and nearest / stochastic rounding.
* :mod:`qmatmul`   -- fused matmul + output re-quantization mirroring the
  multiply -> wide-accumulate -> round/truncate pipeline of Figure 1.

Both are lowered with ``interpret=True`` so the resulting HLO runs on the
CPU PJRT client (real-TPU Mosaic lowering is compile-only in this image).
Pure-jnp oracles live in :mod:`ref`; pytest + hypothesis compare them.
"""

from . import quantize, qmatmul, ref  # noqa: F401
