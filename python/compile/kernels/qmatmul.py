"""Pallas fused matmul + bias + output re-quantization (Figure 1, steps 1-3).

The paper's arithmetic pipeline for one layer (eq. 1 + Figure 1) is:

  step 1: multiply the (already fixed-point) operands,
  step 2: accumulate in a register wider than the operand product
          (bias is added into the same wide accumulator),
  step 3: round/truncate the accumulator to the activation format.

On TPU the wide accumulator is the MXU's f32 accumulation of the operand
products; this kernel reproduces the structure exactly: a tiled
``(M/bm, N/bn, K/bk)`` grid matmul accumulating in the f32 output tile,
with bias-add and the output quantizer applied once, on the final K step.
The quantization parameters (step/lo/hi/enable) are runtime tensors so a
single compiled executable serves the whole experiment grid.

Used by the L2 model for the fully-connected layers; conv layers use
XLA's native convolution followed by the elementwise quantizer (DESIGN.md
section 3).  Lowered with ``interpret=True`` on this image.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes.  (bm, bk) and (bk, bn) f32 tiles must fit VMEM
# simultaneously with the (bm, bn) accumulator: 3 * 128^2 * 4B = 192 KiB,
# far under the 16 MiB budget; 128 is also the MXU systolic dimension.
BM = 128
BN = 256
BK = 512


def _kernel(a_ref, b_ref, bias_ref, step_ref, lo_ref, hi_ref, en_ref, o_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # steps 1+2: multiply, accumulate wide (f32 accumulator tile)
    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    # step 3: bias into the accumulator, then round/truncate once
    @pl.when(k == nk - 1)
    def _requant():
        acc = o_ref[...] + bias_ref[...][None, :]
        step = step_ref[0]
        q = jnp.clip(jnp.floor(acc / step + 0.5), lo_ref[0], hi_ref[0]) * step
        en = en_ref[0]
        o_ref[...] = en * q + (1.0 - en) * acc


def _pad_to(x, rows, cols):
    pr = (-x.shape[0]) % rows
    pc = (-x.shape[1]) % cols
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)))
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def qmatmul(a, b, bias, step, lo, hi, enable, *, bm: int = BM, bn: int = BN, bk: int = BK):
    """``requant(a @ b + bias)`` with runtime quantization parameters.

    a: (M, K) f32, b: (K, N) f32, bias: (N,) f32;
    step/lo/hi/enable: (1,) f32 tensors.  ``enable`` in {0,1}: 0 bypasses
    the output quantizer (float rows of the experiment grid).
    Padding to tile multiples is handled here and stripped on return.
    """
    m, kdim = a.shape
    k2, n = b.shape
    assert kdim == k2, (a.shape, b.shape)
    assert bias.shape == (n,), (bias.shape, n)
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, kdim)
    a_p = _pad_to(a, bm_, bk_)
    b_p = _pad_to(b, bk_, bn_)
    bias_p = jnp.pad(bias, (0, b_p.shape[1] - n)) if b_p.shape[1] != n else bias
    gm, gn, gk = a_p.shape[0] // bm_, b_p.shape[1] // bn_, a_p.shape[1] // bk_
    out = pl.pallas_call(
        functools.partial(_kernel, nk=gk),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),
            pl.BlockSpec((bn_,), lambda i, j, k: (j,)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((a_p.shape[0], b_p.shape[1]), jnp.float32),
        interpret=True,
    )(a_p, b_p, bias_p, step, lo, hi, enable)
    return out[:m, :n]


@jax.custom_vjp
def qmatmul_ste_jnp(a, b, bias, step, lo, hi, enable):
    """Pure-jnp twin of :func:`qmatmul_ste` (perf-ablation backend)."""
    acc = jnp.matmul(a, b, preferred_element_type=jnp.float32) + bias[None, :]
    q = jnp.clip(jnp.floor(acc / step + 0.5), lo, hi) * step
    return enable * q + (1.0 - enable) * acc


def _qmm_jnp_fwd(a, b, bias, step, lo, hi, enable):
    return qmatmul_ste_jnp(a, b, bias, step, lo, hi, enable), (a, b)


def _qmm_jnp_bwd(res, g):
    a, b = res
    ga = jnp.matmul(g, b.T, preferred_element_type=jnp.float32)
    gb = jnp.matmul(a.T, g, preferred_element_type=jnp.float32)
    return (ga, gb, jnp.sum(g, axis=0), None, None, None, None)


qmatmul_ste_jnp.defvjp(_qmm_jnp_fwd, _qmm_jnp_bwd)


@jax.custom_vjp
def qmatmul_ste(a, b, bias, step, lo, hi, enable):
    """STE wrapper: forward = fused quantized pipeline, backward = gradients
    of the *float* ``a @ b + bias`` (the paper's presumed-gradient
    semantics -- this is where the gradient mismatch physically enters).
    custom_vjp because the Pallas call has no autodiff rule."""
    return qmatmul(a, b, bias, step, lo, hi, enable)


def _qmm_fwd(a, b, bias, step, lo, hi, enable):
    return qmatmul_ste(a, b, bias, step, lo, hi, enable), (a, b)


def _qmm_bwd(res, g):
    a, b = res
    ga = jnp.matmul(g, b.T, preferred_element_type=jnp.float32)
    gb = jnp.matmul(a.T, g, preferred_element_type=jnp.float32)
    gbias = jnp.sum(g, axis=0)
    return (ga, gb, gbias, None, None, None, None)


qmatmul_ste.defvjp(_qmm_fwd, _qmm_bwd)
