"""Pure-jnp oracles for the L1 kernels and the paper's arithmetic model.

Everything here is the *specification*; the Pallas kernels must match it
bit-for-bit (nearest rounding) or statistically (stochastic rounding).
The Rust fixed-point library (rust/src/fixedpoint/) implements the same
semantics over integers and is cross-checked in rust/tests/.

Fixed-point model (Q-format, signed, saturating):
    a value with bit-width ``B`` and fractional length ``FL`` covers the
    integer grid  {-2^(B-1), ..., 2^(B-1)-1} * 2^-FL.

    quantize(x) = clip(round(x / step), qmin, qmax) * step
        step = 2^-FL,  qmin = -2^(B-1),  qmax = 2^(B-1) - 1

Rounding modes:
    * nearest    -- round half away from zero is what HW round-to-nearest
                    usually means, but ``jnp.round`` is half-to-even; we
                    standardise on floor(x + 0.5) (half up), matching the
                    Rust engine.
    * stochastic -- floor(x + u), u ~ U[0,1): unbiased, the Gupta et al.
                    2015 scheme the paper names as complementary.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Q-format helpers
# ---------------------------------------------------------------------------


def qparams(bits: int, frac: int):
    """(step, qmin, qmax) for a signed Q-format with ``bits`` total bits and
    ``frac`` fractional bits.  ``frac`` may be negative or exceed ``bits``
    (pure scaling); ``bits`` must be >= 2."""
    if bits < 2:
        raise ValueError(f"need >=2 bits for signed fixed point, got {bits}")
    step = 2.0 ** (-frac)
    qmin = -(2.0 ** (bits - 1))
    qmax = 2.0 ** (bits - 1) - 1
    return step, qmin, qmax


def round_half_up(x):
    """floor(x + 0.5): round-to-nearest, ties away from -inf (HW style)."""
    return jnp.floor(x + 0.5)


# ---------------------------------------------------------------------------
# quantize oracle
# ---------------------------------------------------------------------------


def quantize_ref(x, step, qmin, qmax):
    """Reference fixed-point quantizer (nearest rounding)."""
    return jnp.clip(round_half_up(x / step), qmin, qmax) * step


def quantize_bits_ref(x, bits: int, frac: int):
    step, qmin, qmax = qparams(bits, frac)
    return quantize_ref(x, step, qmin, qmax)


def quantize_stochastic_ref(x, step, qmin, qmax, u):
    """Stochastic rounding with externally supplied uniforms ``u`` in [0,1)."""
    return jnp.clip(jnp.floor(x / step + u), qmin, qmax) * step


# ---------------------------------------------------------------------------
# counter-based uniform generator (shared spec with the Pallas kernel)
# ---------------------------------------------------------------------------


def _mix32(h):
    """finalizer of MurmurHash3 over uint32 -- cheap, well-mixed."""
    h = jnp.uint32(h)
    h = h ^ (h >> jnp.uint32(16))
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> jnp.uint32(13))
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> jnp.uint32(16))
    return h


def hash_uniform_ref(counters, seed):
    """U[0,1) from uint32 counters + uint32 seed (counter-based PRNG).

    The same function is evaluated inside the Pallas kernel so stochastic
    rounding is reproducible across the oracle, the kernel, and (with the
    same integer math) the Rust engine.
    """
    counters = jnp.asarray(counters, jnp.uint32)
    seed = jnp.uint32(seed)
    h = _mix32(counters * jnp.uint32(0x9E3779B9) + seed)
    # 24 high bits -> [0,1) with f32-exact spacing
    return (h >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


# ---------------------------------------------------------------------------
# fused quantized matmul oracle (Figure 1 steps 1-3)
# ---------------------------------------------------------------------------


def qmatmul_ref(a, b, step, qmin, qmax, enable=1.0):
    """C = requant(A @ B): multiply (step 1), wide accumulate (step 2 -- f32
    here stands in for the >=32-bit accumulator), round/truncate (step 3).
    ``enable`` in {0,1} bypasses the output quantizer when 0 (float rows of
    the experiment grid reuse the same compiled executable)."""
    acc = jnp.matmul(a, b, preferred_element_type=jnp.float32)
    q = quantize_ref(acc, step, qmin, qmax)
    return enable * q + (1.0 - enable) * acc


# ---------------------------------------------------------------------------
# the paper's Figure 2: presumed vs effective activation function
# ---------------------------------------------------------------------------


def effective_relu_ref(x, bits: int, frac: int):
    """The *effective* activation function of a fixed-point layer
    (Figure 2b): ReLU followed by the output quantization step."""
    step, qmin, qmax = qparams(bits, frac)
    return quantize_ref(jnp.maximum(x, 0.0), step, qmin, qmax)


def presumed_relu_ref(x):
    """What the backward pass assumes (Figure 2a)."""
    return jnp.maximum(x, 0.0)


# ---------------------------------------------------------------------------
# numpy twins (used by tests that want no jax tracing)
# ---------------------------------------------------------------------------


def quantize_np(x, bits: int, frac: int):
    step, qmin, qmax = qparams(bits, frac)
    return np.clip(np.floor(np.asarray(x) / step + 0.5), qmin, qmax) * step
