"""Pallas elementwise fixed-point quantizer (the paper's Figure 1, step 3).

One kernel, two rounding modes:

* ``mode="nearest"``    -- round-to-nearest (half up), the deterministic
  quantizer used throughout the paper's experiments.
* ``mode="stochastic"`` -- floor(x/step + u), u ~ U[0,1) from a
  counter-based hash (seed is a runtime input), the Gupta et al. 2015
  scheme the paper names as the complementary technique.

All quantization *parameters* (step, qmin, qmax) are runtime tensors, so
a single AOT-compiled executable serves every (bit-width, fractional
length) cell of the experiment grid -- nothing is recompiled when the
Rust coordinator sweeps formats.

TPU mapping (DESIGN.md section 8): this is a VPU elementwise kernel; the
BlockSpec tiles HBM->VMEM traffic in (BLOCK_ROWS x cols) slabs.  On this
image it is lowered with ``interpret=True`` so the CPU PJRT client can
execute the resulting HLO.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Upper bound on rows per grid step.  Chosen in the perf pass: large
# enough that the interpret-mode grid loop is negligible, small enough
# that a VMEM tile (BLOCK_ROWS x cols x 4B) stays well under the ~16 MiB
# TPU budget for every tensor in the model (see EXPERIMENTS.md sec. Perf).
BLOCK_ROWS = 16384


def _pick_block(rows: int, block) -> int:
    """Whole array when it is small; otherwise the configured tile."""
    if block is None:
        block = BLOCK_ROWS
    return min(rows, block)


def _mix32(h):
    h = h ^ (h >> jnp.uint32(16))
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> jnp.uint32(13))
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> jnp.uint32(16))
    return h


def _kernel_nearest(x_ref, step_ref, lo_ref, hi_ref, o_ref):
    x = x_ref[...]
    step = step_ref[0]
    inv = 1.0 / step
    q = jnp.clip(jnp.floor(x * inv + 0.5), lo_ref[0], hi_ref[0])
    o_ref[...] = q * step


def _kernel_stochastic(x_ref, step_ref, lo_ref, hi_ref, seed_ref, o_ref, *, ncols):
    i = pl.program_id(0)
    x = x_ref[...]
    step = step_ref[0]
    inv = 1.0 / step
    # Counter-based uniforms: global element index + seed -> U[0,1).
    rows = x.shape[0]
    base = (jnp.uint32(i) * jnp.uint32(rows * ncols)).astype(jnp.uint32)
    idx = base + jax.lax.broadcasted_iota(jnp.uint32, x.shape, 0) * jnp.uint32(
        ncols
    ) + jax.lax.broadcasted_iota(jnp.uint32, x.shape, 1)
    h = _mix32(idx * jnp.uint32(0x9E3779B9) + seed_ref[0].astype(jnp.uint32))
    u = (h >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))
    q = jnp.clip(jnp.floor(x * inv + u), lo_ref[0], hi_ref[0])
    o_ref[...] = q * step


def _pad_rows(x2d, block):
    rows = x2d.shape[0]
    pad = (-rows) % block
    if pad:
        x2d = jnp.pad(x2d, ((0, pad), (0, 0)))
    return x2d, rows


@functools.partial(jax.jit, static_argnames=("block",))
def quantize(x, step, lo, hi, *, block=None):
    """Quantize ``x`` (any shape) to the fixed-point grid described by the
    (1,)-shaped runtime tensors ``step``, ``lo``, ``hi`` with
    round-to-nearest.  Returns a tensor of ``x``'s shape and dtype."""
    shape = x.shape
    x2d = x.reshape(-1, shape[-1]) if x.ndim >= 2 else x.reshape(-1, 1)
    block = _pick_block(x2d.shape[0], block)
    x2d, rows = _pad_rows(x2d, block)
    ncols = x2d.shape[1]
    grid = (x2d.shape[0] // block,)
    out = pl.pallas_call(
        _kernel_nearest,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, ncols), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((block, ncols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2d.shape, x.dtype),
        interpret=True,
    )(x2d, step, lo, hi)
    return out[:rows].reshape(shape)


@functools.partial(jax.jit, static_argnames=("block",))
def quantize_stochastic(x, step, lo, hi, seed, *, block=None):
    """Stochastic-rounding variant; ``seed`` is a (1,)-shaped uint32/int32
    runtime tensor.  Same counter-based hash as ``ref.hash_uniform_ref``."""
    shape = x.shape
    x2d = x.reshape(-1, shape[-1]) if x.ndim >= 2 else x.reshape(-1, 1)
    block = _pick_block(x2d.shape[0], block)
    x2d, rows = _pad_rows(x2d, block)
    ncols = x2d.shape[1]
    grid = (x2d.shape[0] // block,)
    out = pl.pallas_call(
        functools.partial(_kernel_stochastic, ncols=ncols),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, ncols), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((block, ncols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2d.shape, x.dtype),
        interpret=True,
    )(x2d, step, lo, hi, seed)
    return out[:rows].reshape(shape)


@jax.custom_vjp
def quantize_ste_jnp(x, step, lo, hi, enable):
    """Pure-jnp twin of :func:`quantize_ste` (same semantics, no Pallas
    call).  Selected by ``model.set_backend("jnp")`` for the perf ablation
    in EXPERIMENTS.md section Perf: it quantifies what the interpret-mode
    Pallas grid loops cost on CPU relative to XLA-native elementwise ops."""
    q = jnp.clip(jnp.floor(x / step + 0.5), lo, hi) * step
    return enable * q + (1.0 - enable) * x


def _ste_jnp_fwd(x, step, lo, hi, enable):
    return quantize_ste_jnp(x, step, lo, hi, enable), None


def _ste_jnp_bwd(_, g):
    return (g, None, None, None, None)


quantize_ste_jnp.defvjp(_ste_jnp_fwd, _ste_jnp_bwd)


@jax.custom_vjp
def quantize_ste(x, step, lo, hi, enable):
    """Straight-through-estimator wrapper used by the L2 model.

    Forward: ``enable * q(x) + (1-enable) * x``  (enable is a (1,) 0/1
    runtime tensor -- float rows of the grid bypass quantization without a
    recompile).  Backward: identity w.r.t. ``x`` -- exactly the "presumed"
    smooth gradient of the paper (Figure 2a), which is what creates the
    gradient mismatch the paper analyses.  Implemented as a custom_vjp
    because the Pallas call itself has no autodiff rule.
    """
    q = quantize(x, step, lo, hi)
    return enable * q + (1.0 - enable) * x


def _ste_fwd(x, step, lo, hi, enable):
    return quantize_ste(x, step, lo, hi, enable), None


def _ste_bwd(_, g):
    return (g, None, None, None, None)


quantize_ste.defvjp(_ste_fwd, _ste_bwd)
