"""L1 quantize kernel vs pure-jnp oracle: the core correctness signal.

hypothesis sweeps shapes / Q-formats / value ranges; every case asserts
bit-exact agreement between the Pallas kernel (interpret=True) and
ref.quantize_ref, plus the fixed-point invariants the Rust property tests
mirror (idempotence, grid membership, saturation, monotonicity).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import quantize as qz
from compile.kernels import ref


def _cfg(bits, frac):
    step, qmin, qmax = ref.qparams(bits, frac)
    return (
        jnp.array([step], jnp.float32),
        jnp.array([qmin], jnp.float32),
        jnp.array([qmax], jnp.float32),
    )


def _rand(shape, scale, seed):
    return (np.random.RandomState(seed).randn(*shape) * scale).astype(np.float32)


# ---------------------------------------------------------------------------
# kernel == oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits,frac", [(4, 2), (8, 4), (8, 6), (16, 8), (2, 0)])
@pytest.mark.parametrize("shape", [(7,), (16, 5), (3, 4, 5), (2, 3, 4, 5)])
def test_kernel_matches_ref(bits, frac, shape):
    x = _rand(shape, 4.0, 0)
    step, lo, hi = _cfg(bits, frac)
    got = np.asarray(qz.quantize(jnp.asarray(x), step, lo, hi))
    want = np.asarray(ref.quantize_ref(jnp.asarray(x), step[0], lo[0], hi[0]))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=60, deadline=None)
@given(
    rows=st.integers(1, 70),
    cols=st.integers(1, 9),
    bits=st.integers(2, 16),
    frac=st.integers(-2, 12),
    scale=st.floats(1e-3, 64.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_hypothesis(rows, cols, bits, frac, scale, seed):
    x = _rand((rows, cols), scale, seed % 2**32)
    step, lo, hi = _cfg(bits, frac)
    got = np.asarray(qz.quantize(jnp.asarray(x), step, lo, hi))
    want = np.asarray(ref.quantize_ref(jnp.asarray(x), step[0], lo[0], hi[0]))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 300),
    bits=st.integers(2, 12),
    frac=st.integers(0, 8),
    block=st.integers(1, 64),
)
def test_block_size_invariance(n, bits, frac, block):
    """Tiling must not change values (padding is stripped correctly)."""
    x = _rand((n, 3), 8.0, n)
    step, lo, hi = _cfg(bits, frac)
    a = np.asarray(qz.quantize(jnp.asarray(x), step, lo, hi, block=block))
    b = np.asarray(qz.quantize(jnp.asarray(x), step, lo, hi, block=None))
    np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# fixed-point invariants (mirrored by rust/src/fixedpoint tests)
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(bits=st.integers(2, 12), frac=st.integers(-1, 10), seed=st.integers(0, 999))
def test_idempotent(bits, frac, seed):
    x = _rand((33, 4), 16.0, seed)
    step, lo, hi = _cfg(bits, frac)
    q1 = qz.quantize(jnp.asarray(x), step, lo, hi)
    q2 = qz.quantize(q1, step, lo, hi)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))


@settings(max_examples=40, deadline=None)
@given(bits=st.integers(2, 12), frac=st.integers(0, 10), seed=st.integers(0, 999))
def test_grid_membership_and_saturation(bits, frac, seed):
    x = _rand((50,), 32.0, seed)
    step, lo, hi = _cfg(bits, frac)
    q = np.asarray(qz.quantize(jnp.asarray(x), step, lo, hi))
    ints = q / float(step[0])
    np.testing.assert_allclose(ints, np.round(ints), atol=1e-4)
    assert ints.min() >= float(lo[0]) - 1e-4
    assert ints.max() <= float(hi[0]) + 1e-4


def test_monotone():
    x = np.linspace(-20, 20, 4001).astype(np.float32)
    step, lo, hi = _cfg(6, 2)
    q = np.asarray(qz.quantize(jnp.asarray(x), step, lo, hi))
    assert (np.diff(q) >= -1e-7).all()


def test_round_half_up():
    """Ties go up: 0.5 -> 1, -0.5 -> 0 (HW convention, matches Rust)."""
    x = jnp.array([0.5, -0.5, 1.5, -1.5, 2.5], jnp.float32)
    step, lo, hi = _cfg(8, 0)
    q = np.asarray(qz.quantize(x, step, lo, hi))
    np.testing.assert_array_equal(q, [1.0, 0.0, 2.0, -1.0, 3.0])


# ---------------------------------------------------------------------------
# stochastic rounding
# ---------------------------------------------------------------------------


def test_stochastic_matches_ref_hash():
    """Kernel's in-kernel hash == ref.hash_uniform_ref on the same counters."""
    x = _rand((64, 8), 2.0, 3)
    step, lo, hi = _cfg(8, 4)
    seed = jnp.array([1234], jnp.int32)
    got = np.asarray(qz.quantize_stochastic(jnp.asarray(x), step, lo, hi, seed))
    counters = np.arange(64 * 8, dtype=np.uint32).reshape(64, 8)
    u = np.asarray(ref.hash_uniform_ref(counters, 1234))
    want = np.asarray(
        ref.quantize_stochastic_ref(jnp.asarray(x), step[0], lo[0], hi[0], u)
    )
    np.testing.assert_array_equal(got, want)


def test_stochastic_unbiased():
    """E[q(x)] ~= x for in-range x: the Gupta et al. 2015 property."""
    x = jnp.full((4000, 1), 0.3, jnp.float32)
    step, lo, hi = _cfg(8, 2)  # step 0.25: 0.3 rounds to 0.25 or 0.5
    vals = []
    for s in range(20):
        q = qz.quantize_stochastic(x, step, lo, hi, jnp.array([s], jnp.int32))
        vals.append(float(jnp.mean(q)))
    m = np.mean(vals)
    assert abs(m - 0.3) < 0.005, m


def test_stochastic_determinism():
    x = jnp.asarray(_rand((32, 4), 2.0, 7))
    step, lo, hi = _cfg(8, 3)
    seed = jnp.array([42], jnp.int32)
    a = np.asarray(qz.quantize_stochastic(x, step, lo, hi, seed))
    b = np.asarray(qz.quantize_stochastic(x, step, lo, hi, seed))
    np.testing.assert_array_equal(a, b)
    c = np.asarray(qz.quantize_stochastic(x, step, lo, hi, jnp.array([43], jnp.int32)))
    assert (a != c).any()


# ---------------------------------------------------------------------------
# STE semantics
# ---------------------------------------------------------------------------


def test_ste_forward_and_gradient():
    import jax

    x = jnp.asarray(_rand((16, 4), 4.0, 11))
    step, lo, hi = _cfg(6, 2)
    en = jnp.array([1.0], jnp.float32)

    def f(x):
        return jnp.sum(qz.quantize_ste(x, step, lo, hi, en) ** 2)

    # forward is the quantized value
    y = qz.quantize_ste(x, step, lo, hi, en)
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(qz.quantize(x, step, lo, hi))
    )
    # backward is the float gradient: d/dx sum(q(x)^2) via STE = 2*q(x)
    g = jax.grad(f)(x)
    np.testing.assert_allclose(np.asarray(g), 2 * np.asarray(y), rtol=1e-5)


def test_ste_enable_bypass():
    x = jnp.asarray(_rand((8, 3), 4.0, 13))
    step, lo, hi = _cfg(4, 1)
    off = jnp.array([0.0], jnp.float32)
    y = qz.quantize_ste(x, step, lo, hi, off)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


# ---------------------------------------------------------------------------
# Figure 2: effective activation function is a staircase
# ---------------------------------------------------------------------------


def test_effective_relu_staircase():
    x = jnp.linspace(-2.0, 4.0, 1201)
    eff = np.asarray(ref.effective_relu_ref(x, bits=4, frac=1))
    # staircase: few distinct levels, each a multiple of step
    levels = np.unique(eff)
    assert len(levels) <= 2 ** 3 + 1  # 4-bit signed, positive half + zero
    np.testing.assert_allclose(levels / 0.5, np.round(levels / 0.5), atol=1e-6)
    # negative inputs all collapse to 0
    assert (eff[np.asarray(x) < -0.25] == 0).all()
    # and it deviates from the presumed smooth ReLU
    smooth = np.asarray(ref.presumed_relu_ref(x))
    assert np.abs(eff - smooth).max() >= 0.24
