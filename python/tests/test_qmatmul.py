"""L1 fused qmatmul kernel vs oracle (Figure 1 steps 1-3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import qmatmul as qm
from compile.kernels import ref


def _cfg(bits, frac):
    step, qmin, qmax = ref.qparams(bits, frac)
    return (
        jnp.array([step], jnp.float32),
        jnp.array([qmin], jnp.float32),
        jnp.array([qmax], jnp.float32),
    )


def _rand(shape, scale, seed):
    return (np.random.RandomState(seed).randn(*shape) * scale).astype(np.float32)


def _oracle(a, b, bias, step, lo, hi, en):
    acc = a @ b + bias[None, :]
    if en:
        return np.asarray(
            ref.quantize_ref(jnp.asarray(acc), float(step[0]), float(lo[0]), float(hi[0]))
        )
    return acc


@pytest.mark.parametrize(
    "m,k,n", [(4, 8, 4), (16, 16, 16), (128, 128, 128), (130, 70, 33), (1, 5, 1)]
)
@pytest.mark.parametrize("bits,frac", [(8, 4), (16, 8)])
def test_matches_oracle(m, k, n, bits, frac):
    a = _rand((m, k), 1.0, 1)
    b = _rand((k, n), 1.0, 2)
    bias = _rand((n,), 1.0, 3)
    step, lo, hi = _cfg(bits, frac)
    en = jnp.array([1.0], jnp.float32)
    got = np.asarray(qm.qmatmul(jnp.asarray(a), jnp.asarray(b), jnp.asarray(bias),
                                step, lo, hi, en))
    want = _oracle(a, b, bias, step, lo, hi, True)
    # f32 accumulation order may differ between the tiled kernel and the
    # oracle; at a rounding tie that moves the result by exactly one step.
    diff = np.abs(got - want)
    step_f = float(step[0])
    assert ((diff < 1e-4) | (np.isclose(diff, step_f, atol=1e-4))).all()
    assert (diff > 1e-4).mean() < 0.01


@settings(max_examples=30, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 50),
    n=st.integers(1, 40),
    bits=st.integers(4, 16),
    frac=st.integers(0, 10),
    seed=st.integers(0, 10**6),
)
def test_matches_oracle_hypothesis(m, k, n, bits, frac, seed):
    a = _rand((m, k), 1.0, seed)
    b = _rand((k, n), 1.0, seed + 1)
    bias = _rand((n,), 0.5, seed + 2)
    step, lo, hi = _cfg(bits, frac)
    en = jnp.array([1.0], jnp.float32)
    got = np.asarray(qm.qmatmul(jnp.asarray(a), jnp.asarray(b), jnp.asarray(bias),
                                step, lo, hi, en))
    want = _oracle(a, b, bias, step, lo, hi, True)
    # accumulation order may differ across tiles -> allow f32 roundoff at
    # the rounding boundary: values must land on the same grid point except
    # where the accumulator sits within eps of a tie.
    diff = np.abs(got - want)
    step_f = float(step[0])
    assert ((diff < 1e-4) | (np.isclose(diff, step_f, atol=1e-4))).all()
    assert (diff > 1e-4).mean() < 0.02  # ties are rare


def test_enable_bypass_is_float_matmul():
    a = _rand((17, 9), 1.0, 5)
    b = _rand((9, 13), 1.0, 6)
    bias = _rand((13,), 1.0, 7)
    step, lo, hi = _cfg(4, 2)
    en = jnp.array([0.0], jnp.float32)
    got = np.asarray(qm.qmatmul(jnp.asarray(a), jnp.asarray(b), jnp.asarray(bias),
                                step, lo, hi, en))
    np.testing.assert_allclose(got, a @ b + bias[None, :], rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (32, 16, 8), (128, 128, 128)])
def test_tile_invariance(bm, bn, bk):
    """Result must not depend on the tiling (up to rounding-tie roundoff)."""
    a = _rand((48, 40), 1.0, 8)
    b = _rand((40, 24), 1.0, 9)
    bias = _rand((24,), 1.0, 10)
    step, lo, hi = _cfg(8, 5)
    en = jnp.array([1.0], jnp.float32)
    got = np.asarray(qm.qmatmul(jnp.asarray(a), jnp.asarray(b), jnp.asarray(bias),
                                step, lo, hi, en, bm=bm, bn=bn, bk=bk))
    want = np.asarray(qm.qmatmul(jnp.asarray(a), jnp.asarray(b), jnp.asarray(bias),
                                 step, lo, hi, en))
    diff = np.abs(got - want)
    assert ((diff < 1e-4) | (np.isclose(diff, float(step[0]), atol=1e-4))).all()


def test_ste_backward_is_float_gradient():
    a = jnp.asarray(_rand((6, 5), 1.0, 11))
    b = jnp.asarray(_rand((5, 4), 1.0, 12))
    bias = jnp.asarray(_rand((4,), 1.0, 13))
    step, lo, hi = _cfg(6, 3)
    en = jnp.array([1.0], jnp.float32)

    def f(a, b, bias):
        return jnp.sum(qm.qmatmul_ste(a, b, bias, step, lo, hi, en))

    ga, gb, gbias = jax.grad(f, argnums=(0, 1, 2))(a, b, bias)
    ones = np.ones((6, 4), np.float32)
    np.testing.assert_allclose(np.asarray(ga), ones @ np.asarray(b).T, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(a).T @ ones, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(gbias), ones.sum(0), rtol=1e-5)


def test_ste_forward_is_quantized():
    a = jnp.asarray(_rand((7, 5), 1.0, 14))
    b = jnp.asarray(_rand((5, 3), 1.0, 15))
    bias = jnp.asarray(_rand((3,), 1.0, 16))
    step, lo, hi = _cfg(8, 4)
    en = jnp.array([1.0], jnp.float32)
    y = np.asarray(qm.qmatmul_ste(a, b, bias, step, lo, hi, en))
    w = np.asarray(qm.qmatmul(a, b, bias, step, lo, hi, en))
    np.testing.assert_array_equal(y, w)
