"""L2 model semantics: shapes, quantization plumbing, training dynamics,
and the paper's gradient-mismatch phenomenon itself."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def _float_cfg(L):
    """Quantization disabled everywhere (enable = 0)."""
    one = jnp.ones((L,), jnp.float32)
    zero = jnp.zeros((L,), jnp.float32)
    return (one, -one, one, zero)


def _fx_cfg(L, bits, frac):
    step, qmin, qmax = ref.qparams(bits, frac)
    return (
        jnp.full((L,), step, jnp.float32),
        jnp.full((L,), qmin, jnp.float32),
        jnp.full((L,), qmax, jnp.float32),
        jnp.ones((L,), jnp.float32),
    )


def _batch(arch, n, seed=0):
    spec = model.ARCHS[arch]
    rng = np.random.RandomState(seed)
    x = rng.rand(n, *spec["input"]).astype(np.float32)
    y = rng.randint(0, model.NUM_CLASSES, size=n).astype(np.int32)
    return jnp.asarray(x), jnp.asarray(y)


@pytest.mark.parametrize("arch", ["tiny", "shallow", "paper12"])
def test_param_shapes_consistent(arch):
    shapes = model.param_shapes(arch)
    assert len(shapes) == 2 * model.num_layers(arch)
    params = model.init_params(arch)
    for (name, shape), p in zip(shapes, params):
        assert p.shape == shape, name
    # final layer maps to NUM_CLASSES
    assert shapes[-2][1][-1] == model.NUM_CLASSES


@pytest.mark.parametrize("arch", ["tiny", "shallow"])
def test_forward_shapes(arch):
    L = model.num_layers(arch)
    params = [jnp.asarray(p) for p in model.init_params(arch)]
    x, _ = _batch(arch, 4)
    logits = model.forward(arch, params, x, _float_cfg(L), _float_cfg(L))
    assert logits.shape == (4, model.NUM_CLASSES)


def test_float_cfg_matches_pure_float():
    """enable=0 everywhere must reproduce a plain float CNN."""
    arch = "tiny"
    L = model.num_layers(arch)
    params = [jnp.asarray(p) for p in model.init_params(arch)]
    x, _ = _batch(arch, 4)
    logits = model.forward(arch, params, x, _float_cfg(L), _float_cfg(L))

    # hand-rolled float forward
    h = x
    pi = 0
    li = 0
    for layer in model.ARCHS[arch]["layers"]:
        if layer[0] == "pool":
            h = jax.lax.reduce_window(
                h, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
            )
            continue
        w, b = params[pi], params[pi + 1]
        pi += 2
        if layer[0] == "conv":
            h = jax.lax.conv_general_dilated(
                h, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
            ) + b
        else:
            if h.ndim == 4:
                h = h.reshape(h.shape[0], -1)
            h = h @ w + b
        if li < L - 1:
            h = jnp.maximum(h, 0.0)
        li += 1
    np.testing.assert_allclose(np.asarray(logits), np.asarray(h), rtol=2e-4, atol=2e-4)


def test_quantized_forward_on_grid():
    """With 8/4 activations enabled, every hidden pre-activation effect is
    visible: logits differ from float and are step-quantized at the head."""
    arch = "tiny"
    L = model.num_layers(arch)
    params = [jnp.asarray(p) for p in model.init_params(arch)]
    x, _ = _batch(arch, 4)
    fq = _fx_cfg(L, 8, 4)
    logits_q = model.forward(arch, params, x, fq, fq)
    logits_f = model.forward(arch, params, x, _float_cfg(L), _float_cfg(L))
    assert np.abs(np.asarray(logits_q) - np.asarray(logits_f)).max() > 0
    # logits (last pre-activation) are on the 2^-4 grid
    ints = np.asarray(logits_q) * 16.0
    np.testing.assert_allclose(ints, np.round(ints), atol=1e-3)


def test_train_step_reduces_loss_float():
    arch = "tiny"
    L = model.num_layers(arch)
    spec = model.ARCHS[arch]
    step_fn = jax.jit(model.make_train_step(arch))
    params = [jnp.asarray(p) for p in model.init_params(arch, seed=1)]
    momenta = [jnp.zeros_like(p) for p in params]
    x, y = _batch(arch, spec["train_batch"], seed=2)
    s, lo, hi, en = _float_cfg(L)
    upd = jnp.ones((L,), jnp.float32)
    lr = jnp.array([0.05], jnp.float32)
    mu = jnp.array([0.9], jnp.float32)
    losses = []
    for i in range(12):
        out = step_fn(*params, *momenta, x, y,
                      s, lo, hi, en, s, lo, hi, en, upd, lr, mu)
        params = list(out[: 2 * L])
        momenta = list(out[2 * L: 4 * L])
        losses.append(float(out[-1]))
    assert losses[-1] < losses[0], losses


def test_update_mask_freezes_layers():
    arch = "tiny"
    L = model.num_layers(arch)
    spec = model.ARCHS[arch]
    step_fn = jax.jit(model.make_train_step(arch))
    params = [jnp.asarray(p) for p in model.init_params(arch, seed=3)]
    momenta = [jnp.zeros_like(p) for p in params]
    x, y = _batch(arch, spec["train_batch"], seed=4)
    s, lo, hi, en = _float_cfg(L)
    upd = jnp.zeros((L,), jnp.float32).at[L - 1].set(1.0)  # top layer only
    out = step_fn(*params, *momenta, x, y,
                  s, lo, hi, en, s, lo, hi, en, upd,
                  jnp.array([0.1], jnp.float32), jnp.array([0.0], jnp.float32))
    new_params = list(out[: 2 * L])
    for i in range(2 * L):
        changed = bool(jnp.any(new_params[i] != params[i]))
        is_top = i // 2 == L - 1
        assert changed == is_top, (i, changed)


def test_stats_batch_ranges():
    arch = "tiny"
    L = model.num_layers(arch)
    stats_fn = jax.jit(model.make_stats_batch(arch))
    params = [jnp.asarray(p) for p in model.init_params(arch, seed=5)]
    x, _ = _batch(arch, model.ARCHS[arch]["eval_batch"], seed=6)
    s, lo, hi, en = _float_cfg(L)
    absmax, meanabs, meansq = stats_fn(*params, x, s, lo, hi, en, s, lo, hi, en)
    assert absmax.shape == (L,)
    a, m, q = np.asarray(absmax), np.asarray(meanabs), np.asarray(meansq)
    assert (a > 0).all() and (a >= m).all()
    assert (q <= a * a + 1e-5).all()


def test_eval_batch_loss_and_logits():
    arch = "tiny"
    L = model.num_layers(arch)
    ev = jax.jit(model.make_eval_batch(arch))
    params = [jnp.asarray(p) for p in model.init_params(arch, seed=7)]
    n = model.ARCHS[arch]["eval_batch"]
    x, y = _batch(arch, n, seed=8)
    s, lo, hi, en = _float_cfg(L)
    logits, loss_sum = ev(*params, x, y, s, lo, hi, en, s, lo, hi, en)
    assert logits.shape == (n, model.NUM_CLASSES)
    # untrained net: loss ~ n * ln(10)
    assert abs(float(loss_sum) / n - np.log(10)) < 0.8


def test_gradient_mismatch_grows_with_depth():
    """Section 2.2: the angle between the quantized-path (STE) gradient and
    the float gradient grows toward the bottom of the network."""
    arch = "paper12"
    L = model.num_layers(arch)
    gfn = jax.jit(model.make_grads(arch))
    params = [jnp.asarray(p) for p in model.init_params(arch, seed=9)]
    x, y = _batch(arch, 8, seed=10)
    # pad batch up to the artifact's train batch? grads fn is shape-agnostic
    # here because we jit it fresh -- use batch 8 for speed.
    s, lo, hi, en = _float_cfg(L)
    out_f = gfn(*params, x, y, s, lo, hi, en, s, lo, hi, en)
    fq = _fx_cfg(L, 8, 4)
    # keep logits head at high precision like the paper (16-bit)
    sq, loq, hiq, enq = fq
    s16, l16, h16 = ref.qparams(16, 8)
    sq = sq.at[L - 1].set(s16)
    loq = loq.at[L - 1].set(l16)
    hiq = hiq.at[L - 1].set(h16)
    out_q = gfn(*params, x, y, sq, loq, hiq, enq, sq, loq, hiq, enq)

    def cos(a, b):
        a, b = np.asarray(a).ravel(), np.asarray(b).ravel()
        na, nb = np.linalg.norm(a), np.linalg.norm(b)
        if na == 0 or nb == 0:
            return 0.0
        return float(a @ b / (na * nb))

    # weight-gradient cosine per layer (grads start at index 1, stride 2)
    cs = [cos(out_f[1 + 2 * i], out_q[1 + 2 * i]) for i in range(L)]
    top = np.mean(cs[-3:])
    bottom = np.mean(cs[:3])
    assert top > bottom, cs
    assert top > 0.5, cs
    # at 4 bits the same monotone degradation holds, just more extreme
    # (gradients near-orthogonal in the bottom layers -- exactly why the
    # paper's vanilla 4-bit fine-tuning diverges)
    fq4 = _fx_cfg(L, 4, 2)
    s4, lo4, hi4, en4 = fq4
    s4 = s4.at[L - 1].set(s16)
    lo4 = lo4.at[L - 1].set(l16)
    hi4 = hi4.at[L - 1].set(h16)
    out_q4 = gfn(*params, x, y, s4, lo4, hi4, en4, s4, lo4, hi4, en4)
    cs4 = [cos(out_f[1 + 2 * i], out_q4[1 + 2 * i]) for i in range(L)]
    assert np.mean(cs4[-3:]) > np.mean(cs4[:3]), cs4
    assert np.mean(cs4) < np.mean(cs), (cs4, cs)
