"""AOT pipeline: manifest consistency and HLO-text validity.

These tests lower the `tiny` architecture fresh (not relying on a prior
`make artifacts`) and check the contract the Rust runtime depends on.
"""

import json
import os
import tempfile

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    entry = aot.build_arch("tiny", out)
    return out, entry


def test_hlo_files_written(built):
    out, entry = built
    for kind in model.ARTIFACT_KINDS:
        f = os.path.join(out, entry["artifacts"][kind]["file"])
        assert os.path.exists(f)
        head = open(f).read(200)
        assert "HloModule" in head, head


def test_manifest_io_counts(built):
    _, entry = built
    L = model.num_layers("tiny")
    a = entry["artifacts"]
    assert len(a["train_step"]["inputs"]) == 4 * L + 2 + 8 + 3
    assert len(a["train_step"]["outputs"]) == 4 * L + 1
    assert len(a["eval_batch"]["inputs"]) == 2 * L + 2 + 8
    assert [o["name"] for o in a["eval_batch"]["outputs"]] == ["logits", "loss_sum"]
    assert len(a["stats_batch"]["outputs"]) == 3
    assert len(a["grads"]["outputs"]) == 1 + 2 * L


def test_manifest_shapes_match_model(built):
    _, entry = built
    pshapes = dict(model.param_shapes("tiny"))
    for p in entry["params"]:
        assert tuple(p["shape"]) == pshapes[p["name"]]
    ts = entry["artifacts"]["train_step"]
    by_name = {i["name"]: i for i in ts["inputs"]}
    assert by_name["x"]["shape"] == [model.ARCHS["tiny"]["train_batch"],
                                     *model.ARCHS["tiny"]["input"]]
    assert by_name["y"]["dtype"] == "i32"
    L = model.num_layers("tiny")
    for nm in ("w_step", "a_en", "upd"):
        assert by_name[nm]["shape"] == [L]
    assert by_name["lr"]["shape"] == [1]


def test_input_order_params_first(built):
    _, entry = built
    ts = entry["artifacts"]["train_step"]["inputs"]
    pnames = [n for n, _ in model.param_shapes("tiny")]
    assert [i["name"] for i in ts[: len(pnames)]] == pnames
    assert [i["name"] for i in ts[len(pnames): 2 * len(pnames)]] == [
        f"m.{n}" for n in pnames
    ]


def test_output_order_matches_train_step(built):
    _, entry = built
    outs = [o["name"] for o in entry["artifacts"]["train_step"]["outputs"]]
    pnames = [n for n, _ in model.param_shapes("tiny")]
    assert outs == pnames + [f"m.{n}" for n in pnames] + ["loss"]


def test_manifest_json_round_trip(built):
    out, entry = built
    path = os.path.join(out, "m.json")
    with open(path, "w") as f:
        json.dump({"version": 1, "archs": {"tiny": entry}}, f)
    with open(path) as f:
        back = json.load(f)
    assert back["archs"]["tiny"]["num_layers"] == model.num_layers("tiny")
