"""The pallas and jnp kernel backends must agree bit-for-bit: same STE
semantics, same forward numerics (up to tie-breaking f32 roundoff in the
tiled matmul).  This underwrites the perf ablation in EXPERIMENTS.md --
swapping backends changes speed, never results."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import qmatmul as qm
from compile.kernels import quantize as qz
from compile.kernels import ref


def _cfg(bits, frac):
    step, qmin, qmax = ref.qparams(bits, frac)
    return (
        jnp.array([step], jnp.float32),
        jnp.array([qmin], jnp.float32),
        jnp.array([qmax], jnp.float32),
    )


def test_quantize_backends_agree():
    x = jnp.asarray(np.random.RandomState(0).randn(37, 5).astype(np.float32) * 4)
    step, lo, hi = _cfg(6, 2)
    en = jnp.array([1.0], jnp.float32)
    a = np.asarray(qz.quantize_ste(x, step, lo, hi, en))
    b = np.asarray(qz.quantize_ste_jnp(x, step, lo, hi, en))
    np.testing.assert_array_equal(a, b)


def test_qmatmul_backends_agree():
    r = np.random.RandomState(1)
    a = jnp.asarray(r.randn(20, 30).astype(np.float32))
    b = jnp.asarray(r.randn(30, 10).astype(np.float32))
    bias = jnp.asarray(r.randn(10).astype(np.float32))
    step, lo, hi = _cfg(8, 4)
    en = jnp.array([1.0], jnp.float32)
    pa = np.asarray(qm.qmatmul_ste(a, b, bias, step, lo, hi, en))
    jn = np.asarray(qm.qmatmul_ste_jnp(a, b, bias, step, lo, hi, en))
    # single K-tile here, so even the accumulation order matches
    np.testing.assert_allclose(pa, jn, atol=1e-5)


def test_model_forward_backends_agree():
    arch = "tiny"
    L = model.num_layers(arch)
    params = [jnp.asarray(p) for p in model.init_params(arch, seed=2)]
    x = jnp.asarray(
        np.random.RandomState(3)
        .rand(4, *model.ARCHS[arch]["input"])
        .astype(np.float32)
    )
    step, qmin, qmax = ref.qparams(8, 4)
    cfg = (
        jnp.full((L,), step, jnp.float32),
        jnp.full((L,), qmin, jnp.float32),
        jnp.full((L,), qmax, jnp.float32),
        jnp.ones((L,), jnp.float32),
    )
    try:
        model.set_backend("pallas")
        lp = np.asarray(model.forward(arch, params, x, cfg, cfg))
        model.set_backend("jnp")
        lj = np.asarray(model.forward(arch, params, x, cfg, cfg))
    finally:
        model.set_backend("pallas")
    np.testing.assert_allclose(lp, lj, atol=1e-4)


def test_backend_gradients_agree():
    arch = "tiny"
    L = model.num_layers(arch)
    params = [jnp.asarray(p) for p in model.init_params(arch, seed=4)]
    r = np.random.RandomState(5)
    x = jnp.asarray(r.rand(4, *model.ARCHS[arch]["input"]).astype(np.float32))
    y = jnp.asarray(r.randint(0, 10, size=4).astype(np.int32))
    step, qmin, qmax = ref.qparams(8, 4)
    cfg = (
        jnp.full((L,), step, jnp.float32),
        jnp.full((L,), qmin, jnp.float32),
        jnp.full((L,), qmax, jnp.float32),
        jnp.ones((L,), jnp.float32),
    )

    def loss(backend):
        try:
            model.set_backend(backend)
            return jax.grad(
                lambda p: model.loss_fn(arch, p, x, y, cfg, cfg)
            )(params)
        finally:
            model.set_backend("pallas")

    gp = loss("pallas")
    gj = loss("jnp")
    for a, b in zip(gp, gj):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_set_backend_validates():
    import pytest

    with pytest.raises(ValueError):
        model.set_backend("bogus")
