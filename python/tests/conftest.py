import os
import sys

# make `compile` importable when pytest is run from python/ or repo root
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
